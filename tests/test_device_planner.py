"""Device-resident planner == host Algorithm-1 slicer, byte for byte.

The fused pipeline (repro.core.DevicePlanner → repro.kernels.plan)
promises *byte-identical* plans: same offsets, same coalesced runs,
same §5.2 slice statistics as the host planner — both the per-index
reference (``Slicer(fast_paths=False)``) and the production fast-path
planner.  The fast-lane classes exercise the jnp reference pipeline on
the irregular weather cube (merged datetime, mapped Gaussian latitudes,
cyclic longitude with seam-straddling requests); the slow classes add
the Pallas kernel (interpret mode, so the suite passes on CPU CI) and
hypothesis-generated geometry.
"""

import numpy as np
import pytest

from repro.analysis.plan_check import verify_plan
from repro.core import (Box, ConvexPolytope, DevicePlanner, ExtractionPlan,
                        OrderedAxis, PolytopeExtractor, Request, Select,
                        Slicer, TensorDatacube, batched_plan_2d,
                        batched_plan_runs_2d, compress_plan,
                        decompress_plan, gather)
from repro.dataplane.weather import IrregularWeatherCube, WeatherCube

COUNTRY_NAMES = ("france", "germany", "italy", "norway", "uk")


@pytest.fixture(scope="module")
def iwc():
    return IrregularWeatherCube()      # 96 × 192, cyclic lon


def _iwc_requests(iwc):
    reqs = {c: iwc.country_request(c) for c in COUNTRY_NAMES}
    reqs["seam_box"] = iwc.seam_box_request(35.0, 62.0, -25.0, 25.0)
    return reqs


def _assert_plans_equal(dev, host, label=""):
    dplan, dstats = dev
    hplan, hstats = host
    np.testing.assert_array_equal(dplan.offsets, hplan.offsets, label)
    np.testing.assert_array_equal(dplan.run_starts, hplan.run_starts)
    np.testing.assert_array_equal(dplan.run_lengths, hplan.run_lengths)
    assert dstats.n_slices == hstats.n_slices, label
    assert dstats.n_slices_by_dim == hstats.n_slices_by_dim, label
    assert dstats.n_points == hstats.n_points, label


class TestIrregularWeatherParity:
    """Device plans vs both host planners on the transformed cube."""

    @pytest.mark.parametrize("name", COUNTRY_NAMES + ("seam_box",))
    def test_byte_identical_and_verified(self, iwc, name):
        request = _iwc_requests(iwc)[name]
        dev = DevicePlanner(iwc.cube).plan(request)
        assert dev is not None, f"{name} must be device-plannable"
        _assert_plans_equal(dev, Slicer(iwc.cube,
                                        fast_paths=False).extract_plan(
                                            request), f"{name} vs slow host")
        _assert_plans_equal(dev, Slicer(iwc.cube).extract_plan(request),
                            f"{name} vs fast host")
        plan, stats = dev
        assert plan.coords == {}
        verify_plan(plan, datacube=iwc.cube, stats=stats)

    def test_slicer_entry_point_routes_to_device(self, iwc):
        request = iwc.country_request("france")
        via_slicer = Slicer(iwc.cube,
                            device_planner=True).extract_plan(request)
        direct = DevicePlanner(iwc.cube).plan(request)
        _assert_plans_equal(via_slicer, direct)
        # device plans carry no coords — the entry point preserved that
        assert via_slicer[0].coords == {}
        # verify=True runs the plan checker over the device plan
        Slicer(iwc.cube, device_planner=True,
               verify=True).extract_plan(request)


class TestRegularGridParity:
    def _cube(self, n=32):
        return TensorDatacube([
            OrderedAxis("t", np.arange(3.0)),
            OrderedAxis("x", np.arange(float(n))),
            OrderedAxis("y", np.arange(float(n))),
        ])

    def test_triangle(self):
        cube = self._cube()
        tri = np.array([[4.0, 2.0], [28.0, 9.0], [15.0, 30.0]])
        req = Request([Select("t", [1.0]),
                       ConvexPolytope(("x", "y"), tri)])
        dev = DevicePlanner(cube).plan(req)
        assert dev is not None
        _assert_plans_equal(dev, Slicer(cube,
                                        fast_paths=False).extract_plan(req))
        verify_plan(dev[0], datacube=cube, stats=dev[1])

    def test_empty_intersection(self):
        cube = self._cube()
        req = Request([Box(("x", "y"), [100.0, 100.0], [120.0, 130.0])])
        dev = DevicePlanner(cube).plan(req)
        assert dev is not None
        plan, stats = dev
        hplan, hstats = Slicer(cube).extract_plan(req)
        assert plan.n_points == hplan.n_points == 0
        assert stats.n_points == hstats.n_points == 0

    def test_implicit_all_on_lead_axis(self):
        cube = self._cube()
        req = Request([Box(("x", "y"), [3.0, 4.0], [10.0, 21.0])])
        dev = DevicePlanner(cube).plan(req)
        assert dev is not None
        _assert_plans_equal(dev, Slicer(cube,
                                        fast_paths=False).extract_plan(req))


class TestTransparentFallback:
    def test_octahedral_cube_falls_back(self):
        wc = WeatherCube(n=64, n_times=1, n_levels=1)
        req = wc.country_request("france")
        assert DevicePlanner(wc.cube).plan(req) is None
        fell_back = Slicer(wc.cube, device_planner=True).extract_plan(req)
        host = Slicer(wc.cube).extract_plan(req)
        np.testing.assert_array_equal(fell_back[0].offsets,
                                      host[0].offsets)

    def test_ineligible_request_falls_back(self, iwc):
        # selects on the trailing (lat, lon) axes are outside the
        # pipeline's job shape
        req = iwc.timeseries_request(51.5, 0.0, 0.0, 43200.0)
        assert DevicePlanner(iwc.cube).plan(req) is None
        fell_back = Slicer(iwc.cube, device_planner=True).extract_plan(req)
        host = Slicer(iwc.cube).extract_plan(req)
        np.testing.assert_array_equal(fell_back[0].offsets,
                                      host[0].offsets)


class TestCompressedPlan:
    def test_round_trip_is_exact(self, iwc):
        plan, _ = Slicer(iwc.cube).extract_plan(
            iwc.country_request("france"))
        cp = compress_plan(plan)
        back = decompress_plan(cp)
        np.testing.assert_array_equal(back.offsets, plan.offsets)
        np.testing.assert_array_equal(back.run_starts, plan.run_starts)
        np.testing.assert_array_equal(back.run_lengths, plan.run_lengths)
        assert cp.n_points == plan.n_points
        assert cp.nbytes_encoded < plan.offsets.nbytes

    def test_overlapping_runs_rejected(self):
        plan = ExtractionPlan(offsets=np.arange(10, dtype=np.int64),
                              run_starts=np.array([0, 4], np.int64),
                              run_lengths=np.array([6, 6], np.int64),
                              coords={})
        with pytest.raises(ValueError):
            compress_plan(plan)

    def test_i32_gap_overflow_rejected(self):
        big = 2 ** 31 + 10
        plan = ExtractionPlan(offsets=np.array([0, big], np.int64),
                              run_starts=np.array([0, big], np.int64),
                              run_lengths=np.array([1, 1], np.int64),
                              coords={})
        with pytest.raises(OverflowError):
            compress_plan(plan)


class TestBurstGather:
    def test_matches_per_element_gather(self, iwc):
        import jax.numpy as jnp

        from repro.kernels.gather import ops as gops

        plan, _ = Slicer(iwc.cube).extract_plan(
            iwc.country_request("uk"))
        flat = jnp.asarray(np.arange(iwc.cube.n_elements, dtype=np.float32))
        exp = np.asarray(flat)[plan.offsets]
        for block in (4, 128):
            got = gops.gather_plan_runs(flat, plan.run_starts,
                                        plan.run_lengths, block=block)
            np.testing.assert_array_equal(np.asarray(got), exp)

    def test_extractor_end_to_end(self, iwc):
        import jax.numpy as jnp

        data = iwc.field_data().astype(np.float32)   # device-native dtype
        req = iwc.seam_box_request(35.0, 62.0, -25.0, 25.0)
        pe = PolytopeExtractor(iwc.cube, device_planner=True,
                               burst_gather=True)
        res = pe.extract(req, jnp.asarray(data))
        host = PolytopeExtractor(iwc.cube).extract(req, data)
        np.testing.assert_array_equal(np.asarray(res.values), host.values)


# ---------------------------------------------------------------------------
# slow lane: Pallas kernels (interpret mode) + hypothesis geometry
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestPallasKernelParity:
    """The persistent Pallas pipeline emits the same bytes as its jnp
    reference — full stack, through DevicePlanner, including the cyclic
    seam (uk / seam_box)."""

    @pytest.mark.parametrize("name", ("germany", "uk", "seam_box"))
    def test_pallas_equals_ref(self, iwc, name):
        request = _iwc_requests(iwc)[name]
        ref = DevicePlanner(iwc.cube).plan(request)
        dev = DevicePlanner(iwc.cube, use_pallas=True,
                            interpret=True).plan(request)
        assert ref is not None and dev is not None
        _assert_plans_equal(dev, ref, name)

    def test_pallas_burst_gather(self, iwc):
        import jax.numpy as jnp

        from repro.kernels.gather import ops as gops

        plan, _ = Slicer(iwc.cube).extract_plan(
            iwc.country_request("italy"))
        flat = jnp.asarray(np.arange(iwc.cube.n_elements, dtype=np.float32))
        got = gops.gather_plan_runs(flat, plan.run_starts,
                                    plan.run_lengths, use_pallas=True,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(flat)[plan.offsets])


def _forall_seeds(fn, max_examples: int = 25) -> None:
    """Run a seed-indexed property under hypothesis when available
    (shrinking, example database), else over a deterministic seed
    sweep — the property executes either way."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for seed in range(max_examples):
            fn(seed)
        return
    settings(deadline=None, max_examples=max_examples)(
        given(seed=st.integers(0, 2000))(fn))()


@pytest.mark.slow
class TestHypothesisParity:
    """Property tests: random geometry, device ≡ host, bytes and stats."""

    def _check(self, cube, request):
        dev = DevicePlanner(cube).plan(request)
        assert dev is not None
        _assert_plans_equal(dev, Slicer(cube,
                                        fast_paths=False).extract_plan(
                                            request))
        verify_plan(dev[0], datacube=cube, stats=dev[1])

    def test_random_polygons(self):
        cube = TensorDatacube([OrderedAxis("a", np.arange(24.0)),
                               OrderedAxis("b", np.arange(24.0))])

        def run(seed):
            rng = np.random.default_rng(seed)
            pts = rng.uniform(0, 23, (int(rng.integers(3, 8)), 2))
            self._check(cube, Request([ConvexPolytope(("a", "b"), pts)]))

        _forall_seeds(run)

    def test_random_seam_boxes(self, iwc):
        def run(seed):
            rng = np.random.default_rng(seed)
            lat = np.sort(rng.uniform(-85, 85, 2))
            lon_lo = rng.uniform(-180, 180)
            width = rng.uniform(1.0, 400.0)    # > 360 ⇒ whole circle
            self._check(iwc.cube,
                        iwc.seam_box_request(lat[0], lat[1],
                                             lon_lo, lon_lo + width))

        _forall_seeds(run)


class TestBatchedRunsAdapter:
    def test_runs_equal_offset_lattice(self):
        import jax.numpy as jnp

        from repro.kernels.slice.ops import pack_polytopes
        from repro.core.geometry import Polytope

        tri = np.array([[0.0, 0.0], [6.0, 0.0], [0.0, 6.0]])
        sq = np.array([[2.0, 2.0], [8.5, 2.0], [8.5, 7.5], [2.0, 7.5]])
        verts, valid = pack_polytopes(
            [Polytope(("a", "b"), p) for p in (tri, sq)], v_max=4)
        ax = jnp.arange(10.0)
        offsets, n_points = batched_plan_2d(verts, valid, ax, ax, 10, 10,
                                            max_rows=10, max_cols=10)
        starts, lens, meta = batched_plan_runs_2d(verts, valid, ax, ax,
                                                  max_rows=10)
        n_runs = int(meta[0])
        starts = np.asarray(starts[:n_runs], np.int64)
        lens = np.asarray(lens[:n_runs], np.int64)
        ends = np.cumsum(lens)
        got = (np.repeat(starts, lens)
               + np.arange(int(ends[-1]) if n_runs else 0)
               - np.repeat(ends - lens, lens))
        exp = np.asarray(offsets).ravel()
        np.testing.assert_array_equal(np.sort(got),
                                      np.sort(exp[exp >= 0]))
        assert int(meta[2]) == int(np.asarray(n_points).sum())
