"""On-device batched 2-D extraction == host Algorithm-1 slicer."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (ConvexPolytope, OrderedAxis, Request, Slicer,
                        TensorDatacube)
from repro.core.batched import batched_extract_2d, batched_plan_2d
from repro.kernels.slice.ops import pack_polytopes

settings.register_profile("batched", deadline=None, max_examples=25)
settings.load_profile("batched")

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


def host_offsets(verts: np.ndarray, n0: int, n1: int) -> set[int]:
    cube = TensorDatacube([OrderedAxis("a", np.arange(float(n0))),
                           OrderedAxis("b", np.arange(float(n1)))])
    plan, _ = Slicer(cube).extract_plan(
        Request([ConvexPolytope(("a", "b"), verts)]))
    return set(plan.offsets.tolist())


@given(seed=st.integers(0, 2000))
def test_matches_host_slicer(seed):
    rng = np.random.default_rng(seed)
    n0 = n1 = 16
    polys = [rng.uniform(0, 15, (rng.integers(3, 7), 2))
             for _ in range(6)]
    from repro.core.geometry import Polytope

    verts, valid = pack_polytopes(
        [Polytope(("a", "b"), p) for p in polys], v_max=8)
    offsets, n_points = batched_plan_2d(
        verts, valid, jnp.arange(16.0), jnp.arange(16.0),
        16, 16, max_rows=16, max_cols=16)
    for i, p in enumerate(polys):
        got = set(int(o) for o in np.asarray(offsets[i]).ravel()
                  if o >= 0)
        exp = host_offsets(p, n0, n1)
        # boundary-tolerance slack: discrepancies may only be points on
        # the polytope boundary (same convention as the host tests)
        sym = got ^ exp
        from repro.core.hull import convex_hull_prune
        from scipy.spatial import ConvexHull

        if sym:
            hull = ConvexHull(convex_hull_prune(p), qhull_options="QJ")
            A, b = hull.equations[:, :-1], hull.equations[:, -1]
            for off in sym:
                pt = np.array([off // n1, off % n1], float)
                margin = np.max(pt @ A.T + b)
                assert abs(margin) < 1e-3, (seed, i, off, margin)


def test_extract_values_and_counts():
    tri = np.array([[0.0, 0.0], [6.0, 0.0], [0.0, 6.0]])
    from repro.core.geometry import Polytope

    verts, valid = pack_polytopes([Polytope(("a", "b"), tri)], v_max=4)
    data = jnp.arange(100.0)
    vals, offsets, n_points = batched_extract_2d(
        data, verts, valid, jnp.arange(10.0), jnp.arange(10.0),
        max_rows=8, max_cols=8)
    assert int(n_points[0]) == 28           # proven by the host tests
    got = sorted(int(v) for v, o in
                 zip(np.asarray(vals[0]), np.asarray(offsets[0]).ravel())
                 if o >= 0)
    exp = sorted(x * 10 + y for x in range(10) for y in range(10)
                 if x + y <= 6.0000001)
    assert got == exp


def test_padding_is_minus_one_and_zero_valued():
    sq = np.array([[2.0, 2.0], [3.0, 2.0], [3.0, 3.0], [2.0, 3.0]])
    from repro.core.geometry import Polytope

    verts, valid = pack_polytopes([Polytope(("a", "b"), sq)], v_max=4)
    data = jnp.ones(64)
    vals, offsets, n_points = batched_extract_2d(
        data, verts, valid, jnp.arange(8.0), jnp.arange(8.0),
        max_rows=4, max_cols=4)
    assert int(n_points[0]) == 4
    off = np.asarray(offsets[0]).ravel()
    np.testing.assert_array_equal(np.asarray(vals[0])[off < 0], 0)
