"""Model-level invariants beyond the smoke tests: equivariance,
decode/prefill consistency, chunked-CE equivalence, MoE semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.layers import (cross_entropy, cross_entropy_tied_chunked)
from repro.models.moe import MoEConfig, moe_ffn, moe_init
from repro.models.nequip import (NequIPConfig, gaunt, nequip_energy_forces,
                                 nequip_forward, nequip_init, sph_harm_np,
                                 tp_paths)
from repro.models.transformer import (TransformerConfig, decode_step,
                                      forward, init_params, prefill)

settings.register_profile("models", deadline=None, max_examples=15)
settings.load_profile("models")

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


class TestGaunt:
    def test_orthonormality_of_sh(self):
        # ∫ Y_lm Y_l'm' Y_00 dΩ = δ δ / (2√π)
        for l in (0, 1, 2):
            g = gaunt(l, l, 0)
            np.testing.assert_allclose(
                g[:, :, 0], np.eye(2 * l + 1) / (2 * np.sqrt(np.pi)),
                atol=1e-10)

    def test_parity_selection_rule(self):
        # odd total l vanishes
        assert np.abs(gaunt(0, 1, 0)).max() < 1e-12
        assert np.abs(gaunt(1, 2, 2)).max() < 1e-12

    def test_symmetry_under_argument_swap(self):
        g12 = gaunt(1, 2, 1)
        g21 = gaunt(2, 1, 1)
        np.testing.assert_allclose(g12, np.swapaxes(g21, 0, 1),
                                   atol=1e-12)


class TestEquivariance:
    def _setup(self, readout, n_out):
        cfg = NequIPConfig(n_layers=2, channels=8, d_feat=4,
                           n_out=n_out, readout=readout)
        params = nequip_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        n, e = 16, 48
        pos = jnp.asarray(rng.uniform(0, 4, (n, 3)), jnp.float32)
        feat = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
        ei = jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32)
        return cfg, params, pos, feat, ei

    @given(seed=st.integers(0, 100))
    def test_rotation_invariance_of_scalars(self, seed):
        from scipy.spatial.transform import Rotation

        cfg, params, pos, feat, ei = self._setup("node_class", 3)
        R = jnp.asarray(Rotation.random(
            random_state=seed).as_matrix(), jnp.float32)
        out = nequip_forward(params, cfg, feat, pos, ei)
        out_r = nequip_forward(params, cfg, feat, pos @ R.T, ei)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                                   atol=5e-3)

    def test_force_equivariance(self):
        from scipy.spatial.transform import Rotation

        cfg, params, pos, feat, ei = self._setup("energy", 1)
        R = jnp.asarray(Rotation.random(random_state=3).as_matrix(),
                        jnp.float32)
        e1, f1 = nequip_energy_forces(params, cfg, feat, pos, ei)
        e2, f2 = nequip_energy_forces(params, cfg, feat, pos @ R.T, ei)
        np.testing.assert_allclose(float(e1[0]), float(e2[0]), atol=5e-3)
        np.testing.assert_allclose(np.asarray(f1 @ R.T), np.asarray(f2),
                                   atol=5e-3)

    def test_translation_invariance(self):
        cfg, params, pos, feat, ei = self._setup("node_class", 3)
        out = nequip_forward(params, cfg, feat, pos, ei)
        out_t = nequip_forward(params, cfg, feat, pos + 7.3, ei)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_t),
                                   atol=1e-4)


class TestChunkedCE:
    @given(v=st.integers(10, 200), chunk=st.integers(3, 64),
           seed=st.integers(0, 1000))
    def test_matches_dense(self, v, chunk, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        h = jax.random.normal(k1, (3, 5, 8))
        table = jax.random.normal(k2, (v, 8)) * 0.3
        labels = jax.random.randint(k3, (3, 5), 0, v)
        dense = cross_entropy(h @ table.T, labels)
        chunked = cross_entropy_tied_chunked(h, table, labels,
                                             chunk=chunk)
        np.testing.assert_allclose(float(dense), float(chunked),
                                   rtol=1e-4)

    def test_gradients_match(self):
        key = jax.random.PRNGKey(0)
        h = jax.random.normal(key, (2, 4, 8))
        table = jax.random.normal(jax.random.PRNGKey(1), (50, 8))
        labels = jax.random.randint(key, (2, 4), 0, 50)
        g1 = jax.grad(lambda t: cross_entropy(h @ t.T, labels))(table)
        g2 = jax.grad(lambda t: cross_entropy_tied_chunked(
            h, t, labels, chunk=7))(table)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-3, atol=1e-5)


class TestMoE:
    def test_gates_sum_to_one_reconstruction(self):
        """With 1 expert, MoE == that expert's FFN exactly."""
        cfg = MoEConfig(d_model=8, d_ff=16, n_experts=1, top_k=1,
                        capacity_factor=4.0)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8))
        out, _ = moe_ffn(p, cfg, x)
        xt = x.reshape(8, 8)
        ref = (jax.nn.silu(xt @ p["w_gate"][0]) * (xt @ p["w_up"][0])
               ) @ p["w_down"][0]
        np.testing.assert_allclose(np.asarray(out.reshape(8, 8)),
                                   np.asarray(ref), rtol=2e-5,
                                   atol=2e-5)

    def test_capacity_drops_tokens(self):
        cfg = MoEConfig(d_model=8, d_ff=16, n_experts=2, top_k=1,
                        capacity_factor=0.1)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
        out, _ = moe_ffn(p, cfg, x)
        # capacity = max(1, .1*32/2)=1 → at most 2 tokens routed
        nonzero = jnp.sum(jnp.any(out[0] != 0, axis=-1))
        assert int(nonzero) <= 4

    def test_dropless_keeps_all(self):
        cfg = MoEConfig(d_model=8, d_ff=16, n_experts=2, top_k=1,
                        capacity_factor=0.1)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
        out, _ = moe_ffn(p, cfg, x, dropless=True)
        nonzero = jnp.sum(jnp.any(out[0] != 0, axis=-1))
        assert int(nonzero) == 32


class TestDecodeConsistency:
    @pytest.mark.parametrize("attn", ["gqa", "mla"])
    def test_greedy_continuation_matches_forward(self, attn):
        if attn == "mla":
            cfg = TransformerConfig(
                name="t", vocab=64, d_model=32, n_layers=2, n_heads=4,
                n_kv_heads=4, d_head=8, d_ff=64, attn_type="mla",
                q_lora_rank=16, kv_lora_rank=16, qk_nope_dim=8,
                qk_rope_dim=4, v_head_dim=8, q_chunk=None)
        else:
            cfg = TransformerConfig(
                name="t", vocab=64, d_model=32, n_layers=2, n_heads=4,
                n_kv_heads=2, d_head=8, d_ff=64, q_chunk=None)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 64)
        lg, cache = prefill(params, cfg, toks, max_seq=20)
        seq = toks
        pos = 12
        for _ in range(4):
            nxt = jnp.argmax(lg, -1)
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
            full_logits, _ = forward(params, cfg, seq)
            lg, cache = decode_step(params, cfg, cache, nxt,
                                    jnp.full((2,), pos))
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(full_logits[:, -1]),
                rtol=5e-4, atol=5e-4)
            pos += 1
