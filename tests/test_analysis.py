"""Static verification layer (repro.analysis, DESIGN.md §6).

Three analyzer families, each tested two ways:

* fixture corpus — a known-bad snippet per rule, required to fire
  exactly one diagnostic with the expected rule id (and a known-good
  twin required to stay silent);
* the real tree — the analyzers must run clean over src/repro, i.e. the
  CI gate `python -m repro.analysis --all` holds.

Plus plan-check mutation tests: real planner output is mutated (drop an
offset, corrupt a run length, push an offset past 2³¹, …) and every
mutation must be caught.
"""

from __future__ import annotations

import json
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (check_bench_file, check_lock_source, check_plan,
                            lint_source, lint_tree, verify_plan)
from repro.analysis.concurrency import check_lock_discipline
from repro.analysis.plan_check import PlanVerificationError
from repro.core import (Box, OrderedAxis, Polygon, PolytopeExtractor,
                        Request, Select, TensorDatacube)

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"


def small_cube() -> TensorDatacube:
    return TensorDatacube([
        OrderedAxis("t", np.arange(4.0)),
        OrderedAxis("x", np.arange(16.0)),
        OrderedAxis("y", np.arange(16.0)),
    ])


def small_plan():
    cube = small_cube()
    req = Request([Select("t", [1.0]),
                   Box(("x", "y"), [2.0, 3.0], [9.0, 12.0])])
    plan, stats = PolytopeExtractor(cube).plan(req)
    assert plan.n_points > 2
    return cube, plan, stats


# ---------------------------------------------------------------------------
# plan_check
# ---------------------------------------------------------------------------
class TestPlanCheck:
    def test_clean_plan_verifies(self):
        cube, plan, stats = small_plan()
        assert check_plan(plan, datacube=cube, stats=stats) == []
        verify_plan(plan, datacube=cube, stats=stats)  # must not raise

    def test_polygon_plan_verifies(self):
        cube = small_cube()
        tri = np.array([[2.0, 1.0], [14.0, 5.0], [7.0, 15.0]])
        req = Request([Select("t", [0.0]), Polygon(("x", "y"), tri)])
        plan, stats = PolytopeExtractor(cube).plan(req)
        assert check_plan(plan, datacube=cube, stats=stats) == []

    def _rules(self, plan, cube=None, n_elements=None):
        return {d.rule for d in check_plan(plan, datacube=cube,
                                           n_elements=n_elements)}

    def test_dropped_offset_breaks_run_tiling(self):
        cube, plan, _ = small_plan()
        mid = plan.n_points // 2
        bad = replace(plan, offsets=np.delete(plan.offsets, mid), coords={})
        assert "plan-runs-tile" in self._rules(bad, cube)

    def test_corrupt_run_length_is_caught(self):
        cube, plan, _ = small_plan()
        lengths = plan.run_lengths.copy()
        lengths[0] += 1
        bad = replace(plan, run_lengths=lengths)
        assert "plan-runs-tile" in self._rules(bad, cube)

    def test_zero_run_length_is_caught(self):
        cube, plan, _ = small_plan()
        lengths = plan.run_lengths.copy()
        lengths[0] = 0
        bad = replace(plan, run_lengths=lengths)
        assert "plan-run-length" in self._rules(bad, cube)

    def test_offset_past_2_31_is_caught(self):
        _, plan, _ = small_plan()
        offs = plan.offsets.copy()
        offs[-1] = 2 ** 31 + 5
        bad = replace(plan, offsets=offs, coords={})
        diags = check_plan(bad, n_elements=2 ** 32)
        rules = {d.rule for d in diags}
        assert "plan-i32" in rules
        [i32] = [d for d in diags if d.rule == "plan-i32"]
        assert "int32" in i32.message and "4294967296" in i32.message

    def test_out_of_bounds_offset_is_caught(self):
        cube, plan, _ = small_plan()
        offs = plan.offsets.copy()
        offs[-1] = cube.n_elements + 7
        bad = replace(plan, offsets=offs, coords={})
        assert "plan-bounds" in self._rules(bad, cube)

    def test_negative_offset_is_caught(self):
        cube, plan, _ = small_plan()
        offs = plan.offsets.copy()
        offs[0] = -3
        bad = replace(plan, offsets=offs, coords={})
        assert "plan-bounds" in self._rules(bad, cube)

    def test_unsorted_offsets_are_caught(self):
        cube, plan, _ = small_plan()
        offs = plan.offsets.copy()
        offs[[0, -1]] = offs[[-1, 0]]
        bad = replace(plan, offsets=offs, coords={})
        assert "plan-sorted" in self._rules(bad, cube)

    def test_duplicate_offset_is_caught(self):
        cube, plan, _ = small_plan()
        offs = plan.offsets.copy()
        offs[1] = offs[0]
        bad = replace(plan, offsets=offs, coords={})
        assert "plan-dedup" in self._rules(bad, cube)

    def test_coords_length_mismatch_is_caught(self):
        cube, plan, _ = small_plan()
        bad = replace(plan, coords={"x": np.arange(plan.n_points - 1)})
        assert "plan-coords" in self._rules(bad, cube)

    def test_verify_plan_raises_with_diagnostics(self):
        cube, plan, _ = small_plan()
        bad = replace(plan, offsets=np.delete(plan.offsets, 0), coords={})
        with pytest.raises(PlanVerificationError) as e:
            verify_plan(bad, datacube=cube)
        assert e.value.diagnostics

    def test_slice_bound_violation_is_caught(self):
        cube, plan, stats = small_plan()
        stats.n_slices = 10 ** 9
        assert "plan-slice-bound" in {
            d.rule for d in check_plan(plan, datacube=cube, stats=stats)}


class TestPlanCheckProperty:
    """Hypothesis deepening: every structured mutation of a real plan is
    caught by at least one plan-check rule."""

    def test_random_mutations_are_caught(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        cube, plan, _ = small_plan()
        n = plan.n_points

        @settings(max_examples=60, deadline=None)
        @given(kind=st.sampled_from(
                   ["drop", "dup", "swap", "oob", "i32", "runlen"]),
               pos=st.integers(min_value=0, max_value=n - 1),
               delta=st.integers(min_value=1, max_value=5))
        def run(kind, pos, delta):
            offs = plan.offsets.copy()
            lengths = plan.run_lengths.copy()
            if kind == "drop":
                offs = np.delete(offs, pos)
            elif kind == "dup":
                offs[pos] = offs[(pos + 1) % n] if n > 1 else offs[pos]
                offs = np.sort(offs)
            elif kind == "swap":
                offs[[0, -1]] = offs[[-1, 0]]
            elif kind == "oob":
                offs[pos] = cube.n_elements + delta
            elif kind == "i32":
                offs[pos] = 2 ** 31 + delta
            elif kind == "runlen":
                lengths[pos % len(lengths)] += delta
            bad = replace(plan, offsets=offs, run_lengths=lengths,
                          coords={})
            assert check_plan(bad, datacube=cube) != []

        run()


# ---------------------------------------------------------------------------
# lint — one bad snippet per rule, each firing exactly one diagnostic
# ---------------------------------------------------------------------------
class TestLintFixtures:
    def test_float32_literal_in_planner_fires_once(self):
        bad = ("import numpy as np\n"
               "def f(x):\n"
               "    return np.asarray(x, dtype=np.float32)\n")
        diags = lint_source(bad, "core/geometry.py")
        assert [d.rule for d in diags] == ["planner-float32"]

    def test_float32_string_dtype_fires_once(self):
        bad = "def f(x):\n    return x.astype('float32')\n"
        diags = lint_source(bad, "core/slicer.py")
        assert [d.rule for d in diags] == ["planner-float32"]

    def test_float64_planner_is_clean(self):
        good = ("import numpy as np\n"
                "def f(x):\n"
                "    return np.asarray(x, dtype=np.float64)\n")
        assert lint_source(good, "core/hull.py") == []

    def test_float32_outside_planner_files_is_allowed(self):
        ok = ("import jax.numpy as jnp\n"
              "def f(x):\n"
              "    return x.astype(jnp.float32)\n")
        assert lint_source(ok, "models/layers.py") == []

    def test_direct_boolean_mask_subscript_fires_once(self):
        bad = ("def load(cube, threshold):\n"
               "    field = cube.read_all()\n"
               "    return field[field > threshold]\n")
        diags = lint_source(bad, "dataplane/foo.py")
        assert [d.rule for d in diags] == ["load-then-filter"]

    def test_mask_variable_subscript_fires_once(self):
        bad = ("def load(cube, threshold):\n"
               "    field = cube.read_all()\n"
               "    mask = field > threshold\n"
               "    return field[mask]\n")
        diags = lint_source(bad, "dataplane/foo.py")
        assert [d.rule for d in diags] == ["load-then-filter"]

    def test_plan_first_dataplane_is_clean(self):
        good = ("def load(cube, request, data):\n"
                "    plan, _ = cube.plan(request)\n"
                "    return data[plan.offsets]\n")
        assert lint_source(good, "dataplane/foo.py") == []

    def test_mask_filter_outside_dataplane_is_allowed(self):
        ok = "def f(x):\n    return x[x > 0]\n"
        assert lint_source(ok, "benchmarks_helper.py") == []

    def test_unguarded_i32_cast_fires_once(self):
        bad = ("import numpy as np\n"
               "def f(offsets):\n"
               "    return offsets.astype(np.int32)\n")
        diags = lint_source(bad, "core/foo.py")
        assert [d.rule for d in diags] == ["unchecked-i32-cast"]

    def test_i32_constructor_cast_fires_once(self):
        bad = ("import jax.numpy as jnp\n"
               "def f(off):\n"
               "    return jnp.int32(off)\n")
        diags = lint_source(bad, "serve/foo.py")
        assert [d.rule for d in diags] == ["unchecked-i32-cast"]

    def test_raw_cast_in_paged_attn_fires_once(self):
        bad = ("import jax.numpy as jnp\n"
               "def f(block_table):\n"
               "    return block_table.astype(jnp.int32)\n")
        diags = lint_source(bad, "kernels/paged_attn/kernel.py")
        assert [d.rule for d in diags] == ["unchecked-i32-cast"]

    def test_checked_cast_in_paged_attn_is_clean(self):
        good = ("from repro.kernels._casting import checked_cast_i32\n"
                "def f(block_table, n_pages):\n"
                "    return checked_cast_i32(block_table,\n"
                "                            n_elements=n_pages,\n"
                "                            allow_negative_one=True)\n")
        assert lint_source(good, "kernels/paged_attn/kernel.py") == []

    def test_raw_cast_in_segment_fires_once(self):
        bad = ("import numpy as np\n"
               "def f(segment_ids):\n"
               "    return np.int32(segment_ids)\n")
        diags = lint_source(bad, "kernels/segment/kernel.py")
        assert [d.rule for d in diags] == ["unchecked-i32-cast"]

    def test_checked_cast_in_segment_is_clean(self):
        good = ("from repro.kernels._casting import checked_cast_i32\n"
                "def f(segment_ids, num_segments):\n"
                "    return checked_cast_i32(segment_ids,\n"
                "                            n_elements=num_segments,\n"
                "                            allow_negative_one=True)\n")
        assert lint_source(good, "kernels/segment/kernel.py") == []

    def test_raw_cast_in_slice_fires_once(self):
        bad = ("import jax.numpy as jnp\n"
               "def f(plane_rows):\n"
               "    return plane_rows.astype(jnp.int32)\n")
        diags = lint_source(bad, "kernels/slice/ref.py")
        assert [d.rule for d in diags] == ["unchecked-i32-cast"]

    def test_raw_cast_in_plan_fires_once(self):
        bad = ("import jax.numpy as jnp\n"
               "def f(run_starts):\n"
               "    return jnp.int32(run_starts)\n")
        diags = lint_source(bad, "kernels/plan/kernel.py")
        assert [d.rule for d in diags] == ["unchecked-i32-cast"]

    def test_typed_arange_in_plan_is_clean(self):
        # dtype= arguments are not casts — the plan pipeline builds its
        # int32 ramps this way (offsets validated upstream by
        # ensure_i32_addressable / checked_cast_i32).
        good = ("import jax.numpy as jnp\n"
                "def f(ok, n0, n1):\n"
                "    rowoff = jnp.arange(0, n0 * n1, n1, dtype=jnp.int32)\n"
                "    return rowoff, jnp.cumsum(ok, dtype=jnp.int32)\n")
        assert lint_source(good, "kernels/plan/ref.py") == []

    def test_cast_in_uncovered_kernel_dir_is_allowed(self):
        ok = ("import jax.numpy as jnp\n"
              "def f(x):\n"
              "    return x.astype(jnp.int32)\n")
        assert lint_source(ok, "kernels/experimental/foo.py") == []

    def test_cast_in_helper_module_is_allowed(self):
        ok = ("import numpy as np\n"
              "def checked_cast_i32(x):\n"
              "    return x.astype(np.int32)\n")
        assert lint_source(ok, "kernels/_casting.py") == []

    def test_pragma_suppresses_rule(self):
        ok = ("import numpy as np\n"
              "def f(ids):\n"
              "    return ids.astype(np.int32)  "
              "# lint-ok: unchecked-i32-cast\n")
        assert lint_source(ok, "core/foo.py") == []

    def test_i64_cast_is_allowed(self):
        ok = ("import numpy as np\n"
              "def f(offsets):\n"
              "    return offsets.astype(np.int64)\n")
        assert lint_source(ok, "core/foo.py") == []


# ---------------------------------------------------------------------------
# concurrency — lock-discipline fixtures
# ---------------------------------------------------------------------------
LOCKED_BAD = """
import threading

class Service:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def peek(self):
        return self.count
"""

LOCKED_GOOD = LOCKED_BAD.replace(
    "    def peek(self):\n        return self.count\n",
    "    def peek(self):\n        with self._lock:\n"
    "            return self.count\n")

LOCKED_PRAGMA = LOCKED_BAD.replace(
    "        return self.count\n",
    "        return self.count  # unlocked-ok: monotonic probe\n")


class TestLockDiscipline:
    def test_unguarded_read_fires_once(self):
        diags = check_lock_source(LOCKED_BAD, "serve/foo.py")
        assert [d.rule for d in diags] == ["lock-discipline"]
        assert "Service.count" in diags[0].message

    def test_guarded_read_is_clean(self):
        assert check_lock_source(LOCKED_GOOD, "serve/foo.py") == []

    def test_pragma_waives_with_reason(self):
        assert check_lock_source(LOCKED_PRAGMA, "serve/foo.py") == []

    def test_init_writes_are_exempt(self):
        src = ("import threading\n"
               "class S:\n"
               "    def __init__(self):\n"
               "        self._lock = threading.Lock()\n"
               "        self.state = {}\n"
               "    def put(self, k, v):\n"
               "        with self._lock:\n"
               "            self.state[k] = v\n")
        assert check_lock_source(src, "serve/foo.py") == []

    def test_attribute_chain_root_is_protected(self):
        src = ("import threading\n"
               "class S:\n"
               "    def __init__(self):\n"
               "        self._lock = threading.Lock()\n"
               "        self.cache = object()\n"
               "    def record(self):\n"
               "        with self._lock:\n"
               "            self.cache.stats.hits += 1\n"
               "    def probe(self):\n"
               "        return self.cache.stats.hits\n")
        diags = check_lock_source(src, "serve/foo.py")
        assert [d.rule for d in diags] == ["lock-discipline"]

    def test_unguarded_write_fires(self):
        src = ("import threading\n"
               "class S:\n"
               "    def __init__(self):\n"
               "        self._lock = threading.Lock()\n"
               "        self.n = 0\n"
               "    def a(self):\n"
               "        with self._lock:\n"
               "            self.n = 1\n"
               "    def b(self):\n"
               "        self.n = 2\n")
        diags = check_lock_source(src, "serve/foo.py")
        assert [d.rule for d in diags] == ["lock-discipline"]

    def test_lockless_class_is_ignored(self):
        src = ("class Plain:\n"
               "    def __init__(self):\n"
               "        self.x = 0\n"
               "    def f(self):\n"
               "        self.x += 1\n")
        assert check_lock_source(src, "dataplane/foo.py") == []


# The bug PR 5 fixed: PlanCache guarded its writes (put/get under the
# service lock) but left keys()/__contains__ reading the OrderedDict
# bare — an iterating reader races a concurrently mutating writer.
# This fixture is the pre-fix shape; the checker must flag it so the
# regression cannot quietly come back.
PLAN_CACHE_RACE = """
import threading
from collections import OrderedDict

class PlanCache:
    def __init__(self, capacity=1024):
        self._lock = threading.Lock()
        self._od = OrderedDict()
        self.capacity = capacity

    def put(self, key, plan):
        with self._lock:
            self._od[key] = plan

    def __contains__(self, key):
        return key in self._od

    def keys(self):
        return list(self._od)
"""

PLAN_CACHE_FIXED = PLAN_CACHE_RACE.replace(
    "    def __contains__(self, key):\n"
    "        return key in self._od\n",
    "    def __contains__(self, key):\n"
    "        with self._lock:\n"
    "            return key in self._od\n").replace(
    "    def keys(self):\n"
    "        return list(self._od)\n",
    "    def keys(self):\n"
    "        with self._lock:\n"
    "            return list(self._od)\n")


class TestPlanCacheLockRegression:
    def test_unsynchronized_cache_reads_are_flagged(self):
        diags = check_lock_source(PLAN_CACHE_RACE, "serve/extraction.py")
        assert diags and all(d.rule == "lock-discipline" for d in diags)
        # both bare readers fire: __contains__ and keys()
        assert len(diags) == 2
        assert all("PlanCache._od" in d.message for d in diags)

    def test_guarded_cache_reads_are_clean(self):
        assert check_lock_source(PLAN_CACHE_FIXED,
                                 "serve/extraction.py") == []

    def test_real_plan_cache_state_is_inferred(self):
        # The shipped PlanCache must expose its state to the checker:
        # _od and stats inferred protected, every access lock-guarded.
        import ast

        from repro.analysis.concurrency import _ProtectedCollector

        src = (SRC / "serve" / "extraction.py").read_text()
        for node in ast.walk(ast.parse(src)):
            if isinstance(node, ast.ClassDef) and node.name == "PlanCache":
                c = _ProtectedCollector()
                for stmt in node.body:
                    c.visit(stmt)
                assert "_lock" in c.locks
                assert "_od" in c.protected
                assert "stats" in c.protected
                return
        pytest.fail("PlanCache not found")


# ---------------------------------------------------------------------------
# the real tree must be clean (the CI gate)
# ---------------------------------------------------------------------------
class TestRepoTreeClean:
    def test_lint_clean_on_src(self):
        assert [str(d) for d in lint_tree(SRC)] == []

    def test_lock_discipline_clean_on_src(self):
        assert [str(d) for d in check_lock_discipline(SRC)] == []

    def test_service_lock_state_is_inferred(self):
        # The checker must actually see ExtractionService's protected
        # state — guard against the rule silently matching nothing.
        import ast

        from repro.analysis.concurrency import _ProtectedCollector

        src = (SRC / "serve" / "extraction.py").read_text()
        for node in ast.walk(ast.parse(src)):
            if isinstance(node, ast.ClassDef) \
                    and node.name == "ExtractionService":
                c = _ProtectedCollector()
                for stmt in node.body:
                    c.visit(stmt)
                assert "_lock" in c.locks
                assert "cache" in c.protected
                return
        pytest.fail("ExtractionService not found")


# ---------------------------------------------------------------------------
# bench schema
# ---------------------------------------------------------------------------
class TestBenchSchema:
    def test_repo_bench_file_is_clean(self):
        assert [str(d) for d in
                check_bench_file(REPO / "BENCH_extraction.json")] == []

    def test_missing_key_is_caught(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"bench": "extraction", "rows": [
            {"example": "x", "polytope_bytes": 1}]}))
        diags = check_bench_file(p)
        assert diags and all(d.rule == "bench-schema" for d in diags)

    def test_invalid_json_is_caught(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text("{not json")
        assert [d.rule for d in check_bench_file(p)] == ["bench-schema"]

    def test_empty_rows_is_caught(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"bench": "extraction", "rows": []}))
        assert [d.rule for d in check_bench_file(p)] == ["bench-schema"]

    def test_repo_serve_bench_file_is_clean(self):
        assert [str(d) for d in
                check_bench_file(REPO / "BENCH_serve.json")] == []

    def test_serve_row_missing_key_is_caught(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"bench": "serve", "rows": [
            {"scenario": "zipf", "p50_ms": 1.0}]}))
        diags = check_bench_file(p)
        assert diags and all(d.rule == "bench-schema" for d in diags)
        assert any("p99_ms" in d.message for d in diags)

    def test_unknown_bench_tag_is_caught(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"bench": "warp-drive", "rows": [
            {"scenario": "x"}]}))
        diags = check_bench_file(p)
        assert [d.rule for d in diags] == ["bench-schema"]
        assert "serve" in diags[0].message  # lists registered tags


# ---------------------------------------------------------------------------
# checked_cast_i32 — the helper the lint rule funnels everything through
# ---------------------------------------------------------------------------
class TestCheckedCast:
    def test_valid_offsets_cast(self):
        from repro.kernels import checked_cast_i32

        out = checked_cast_i32(np.array([0, 5, 9], np.int64), n_elements=10)
        assert out.dtype == np.int32
        np.testing.assert_array_equal(out, [0, 5, 9])

    def test_overflow_raises_naming_cube_size(self):
        from repro.kernels import checked_cast_i32

        with pytest.raises(OverflowError, match="int32"):
            checked_cast_i32(np.array([2 ** 31 + 3], np.int64))

    def test_index_space_overflow_raises_before_values(self):
        from repro.kernels import checked_cast_i32

        with pytest.raises(OverflowError, match="2147483647"):
            checked_cast_i32(np.array([0], np.int64),
                             n_elements=2 ** 31 + 1)

    def test_out_of_bounds_raises(self):
        from repro.kernels import checked_cast_i32

        with pytest.raises(IndexError):
            checked_cast_i32(np.array([10], np.int64), n_elements=10)

    def test_negative_rejected_unless_padding(self):
        from repro.kernels import checked_cast_i32

        with pytest.raises(IndexError):
            checked_cast_i32(np.array([-1, 3], np.int64), n_elements=10)
        out = checked_cast_i32(np.array([-1, 3], np.int64), n_elements=10,
                               allow_negative_one=True)
        np.testing.assert_array_equal(out, [-1, 3])
        with pytest.raises(IndexError):
            checked_cast_i32(np.array([-2], np.int64), n_elements=10,
                             allow_negative_one=True)

    def test_gather_ref_rejects_oob_rows(self):
        jnp = pytest.importorskip("jax.numpy")
        from repro.kernels.gather import ref

        table = jnp.arange(12.0).reshape(4, 3)
        with pytest.raises(IndexError):
            ref.gather_rows(table, jnp.array([0, 4]))


# ---------------------------------------------------------------------------
# verify=True end-to-end (acceptance: PR 3 weather example, zero diags)
# ---------------------------------------------------------------------------
class TestServiceVerify:
    def test_irregular_weather_round_trip_verified(self):
        from repro.dataplane.weather import IrregularWeatherCube
        from repro.serve.extraction import ExtractionService

        iwc = IrregularWeatherCube(n_lat=48, n_lon=96)
        data = iwc.field_data(seed=3)
        svc = ExtractionService(iwc.cube, verify=True)
        for req in (iwc.country_request("uk"),
                    iwc.seam_box_request(40.0, 60.0, -20.0, 20.0),
                    iwc.timeseries_request(51.5, 0.0, 43200.0,
                                           86400.0 + 43200.0)):
            res = svc.extract(req, data)
            assert res.plan.n_points > 0
            np.testing.assert_array_equal(res.values,
                                          data[res.plan.offsets])

    def test_verify_rejects_corrupted_plan(self):
        cube, plan, stats = small_plan()
        from repro.core.slicer import Slicer

        slicer = Slicer(cube, verify=True)
        # sanity: verified planning works
        p2, _ = slicer.extract_plan(
            Request([Select("t", [0.0]),
                     Box(("x", "y"), [1.0, 1.0], [3.0, 3.0])]))
        assert p2.n_points == 9
