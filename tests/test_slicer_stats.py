"""Slicer accounting regressions: categorical frontier dedupe and the
§5.2 slice-count bound across the vector-leaf / shared-box paths."""

import numpy as np

from repro.core import (Box, CategoricalAxis, ConvexPolytope, OrderedAxis,
                        Request, Select, Slicer, TensorDatacube, Union)


def cat_cube():
    return TensorDatacube([
        CategoricalAxis("param", ["t2m", "u10", "v10"]),
        OrderedAxis("x", np.arange(8.0)),
        OrderedAxis("y", np.arange(8.0)),
    ])


class TestCategoricalDedupe:
    def test_duplicate_values_in_one_select(self):
        cube = cat_cube()
        shapes = [Box(("x", "y"), [0, 0], [5, 5])]
        dup, sdup = Slicer(cube).extract_plan(
            Request([Select("param", ["t2m", "t2m"]), *shapes]))
        one, sone = Slicer(cube).extract_plan(
            Request([Select("param", ["t2m"]), *shapes]))
        np.testing.assert_array_equal(np.sort(dup.offsets),
                                      np.sort(one.offsets))
        # the duplicate label must not double the subtree expansion work
        assert sdup.n_slices == sone.n_slices
        assert sdup.n_slices_by_dim == sone.n_slices_by_dim

    def test_duplicate_values_across_selects(self):
        cube = cat_cube()
        shapes = [Box(("x", "y"), [0, 0], [5, 5])]
        dup, sdup = Slicer(cube).extract_plan(
            Request([Select("param", ["t2m", "u10"]),
                     Select("param", ["t2m"]), *shapes]))
        ref, sref = Slicer(cube).extract_plan(
            Request([Select("param", ["t2m", "u10"]), *shapes]))
        np.testing.assert_array_equal(np.sort(dup.offsets),
                                      np.sort(ref.offsets))
        assert sdup.n_slices == sref.n_slices


class TestSliceCountBound:
    """§5.2: N_slices ≤ Σ_i Π_{j≤i} n_j with n_j the indices found on
    axis j — and by-dim counts must always sum to the total."""

    def test_box_meets_bound_exactly(self):
        n1, n2, n3 = 4, 5, 6
        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(10.0)) for n in "abc"])
        plan, stats = Slicer(cube).extract_plan(Request(
            [Box(("a", "b", "c"), [0, 0, 0],
                 [n1 - 1.0, n2 - 1.0, n3 - 1.0])]))
        # the shared-box and vector-leaf fast paths must report the same
        # counts the per-index path would: exactly the §5.2 bound
        assert stats.n_slices == n1 + n1 * n2 + n1 * n2 * n3
        assert stats.n_slices_by_dim == {3: n1, 2: n1 * n2,
                                         1: n1 * n2 * n3}
        assert plan.n_points == n1 * n2 * n3

    def test_by_dim_sums_to_total(self):
        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(10.0)) for n in "abc"])
        reqs = [
            Request([Box(("a", "b", "c"), [1, 1, 1], [4, 6, 3])]),
            Request([ConvexPolytope(("a", "b", "c"), np.array(
                [[0, 0, 0], [7, 1, 1], [1, 7, 2], [2, 2, 7]], float))]),
            Request([Union([Box(("a", "b"), [0, 0], [3, 3]),
                            Box(("a", "b"), [2, 2], [6, 6])])]),
        ]
        for req in reqs:
            _, stats = Slicer(cube).extract_plan(req)
            assert sum(stats.n_slices_by_dim.values()) == stats.n_slices

    def test_convex_polytope_respects_bound(self):
        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(10.0)) for n in "abc"])
        verts = np.array([[0, 0, 0], [8, 0, 0], [0, 8, 0], [0, 0, 8]],
                         float)
        root, stats = Slicer(cube).build_index_tree(
            Request([ConvexPolytope(("a", "b", "c"), verts)]))
        # per-level node counts from the tree itself: n_1, n_1·n_2, …
        level1 = len(root.children)
        level2 = sum(len(c.children) for c in root.children.values())
        level3 = sum(0 if g.leaf_positions is None else
                     len(g.leaf_positions)
                     for c in root.children.values()
                     for g in c.children.values())
        assert stats.n_slices <= level1 + level2 + level3
