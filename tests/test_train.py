import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (OptimizerConfig, adafactor_init,
                                   adafactor_update, adamw_init,
                                   adamw_update, clip_by_global_norm,
                                   global_norm, make_optimizer, schedule)
from repro.train.train_state import init_train_state, make_train_step

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


def quad_loss(params, batch):
    err = params["w"] - batch["target"]
    return jnp.sum(jnp.square(err)), {}


class TestOptimizers:
    @pytest.mark.parametrize("kind", ["adamw", "adafactor"])
    def test_converges_on_quadratic(self, kind):
        cfg = OptimizerConfig(kind=kind, lr=0.1, weight_decay=0.0,
                              warmup_steps=10, total_steps=500)
        init, update = make_optimizer(cfg)
        params = {"w": jnp.ones((8, 4)) * 5.0}
        target = jnp.full((8, 4), 2.0)
        state = init(params)
        for _ in range(300):
            grads = jax.grad(
                lambda p: quad_loss(p, {"target": target})[0])(params)
            params, state, _ = update(grads, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]), 2.0,
                                   atol=0.3)

    def test_adafactor_state_is_factored(self):
        params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
        st = adafactor_init(params)
        assert st["f"]["w"]["vr"].shape == (64,)
        assert st["f"]["w"]["vc"].shape == (32,)
        assert st["f"]["b"]["v"].shape == (32,)

    def test_adamw_bias_correction_first_step(self):
        cfg = OptimizerConfig(kind="adamw", lr=1e-1, weight_decay=0.0,
                              warmup_steps=0, total_steps=100_000)
        params = {"w": jnp.zeros((4, 4))}
        state = adamw_init(params)
        grads = {"w": jnp.ones((4, 4))}
        new_params, state, m = adamw_update(cfg, grads, state, params)
        # bias-corrected first step ≈ -lr * g/|g|
        np.testing.assert_allclose(np.asarray(new_params["w"]), -0.1,
                                   rtol=1e-3)


class TestSchedule:
    def test_warmup_then_cosine(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=100, total_steps=1000,
                              min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.asarray(0))) == 0.0
        assert abs(float(schedule(cfg, jnp.asarray(100))) - 1.0) < 1e-5
        assert abs(float(schedule(cfg, jnp.asarray(1000)))
                   - 0.1) < 1e-5

    def test_clip(self):
        grads = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(grads, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
        assert float(norm) > 1.0


class TestTrainStep:
    def test_accum_equivalence(self):
        """accum_steps=4 must equal the full-batch gradient step."""
        cfg = OptimizerConfig(kind="adamw", lr=0.01, weight_decay=0.0,
                              warmup_steps=0, total_steps=100)

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean(jnp.square(pred - batch["y"])), {}

        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (8, 2))}
        batch = {"x": jax.random.normal(key, (16, 8)),
                 "y": jax.random.normal(key, (16, 2))}

        s1 = init_train_state(params, cfg)
        s4 = init_train_state(params, cfg)
        step1 = make_train_step(loss_fn, cfg, accum_steps=1)
        step4 = make_train_step(loss_fn, cfg, accum_steps=4)
        s1, m1 = step1(s1, batch)
        s4, m4 = step4(s4, batch)
        # microbatched mean-of-means == full mean here (equal sizes)
        np.testing.assert_allclose(np.asarray(s1["params"]["w"]),
                                   np.asarray(s4["params"]["w"]),
                                   rtol=2e-5, atol=2e-5)

    def test_metrics_contain_loss_and_lr(self):
        cfg = OptimizerConfig(kind="adamw", lr=0.01,
                              warmup_steps=0, total_steps=100)
        params = {"w": jnp.ones((2, 2))}
        step = make_train_step(
            lambda p, b: (jnp.sum(p["w"] ** 2), {}), cfg)
        state = init_train_state(params, cfg)
        _, metrics = step(state, {"unused": jnp.zeros(())})
        assert {"loss", "lr", "grad_norm"} <= set(metrics)
