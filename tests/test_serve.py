import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import TransformerConfig, init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.kv_cache import PagedKVCache

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


def tiny_cfg():
    return TransformerConfig(
        name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=4,
        n_kv_heads=2, d_head=8, d_ff=64, q_chunk=None)


class TestPagedKVCache:
    def test_alloc_release_cycle(self):
        pager = PagedKVCache(n_pages=16, page_size=4,
                             max_pages_per_seq=8)
        pages = pager.allocate(1, 10)
        assert len(pages) == 3
        assert pager.utilization == 3 / 16
        pager.release(1)
        assert pager.utilization == 0.0

    def test_extend_allocates_on_boundary(self):
        pager = PagedKVCache(16, 4, 8)
        pager.allocate(1, 4)       # exactly one page
        assert pager.extend(1) is not None   # crosses into page 2
        assert pager.extend(1) is None

    def test_plan_is_extraction_plan(self):
        pager = PagedKVCache(16, 4, 4)
        pager.allocate(1, 6)
        pager.allocate(2, 3)
        bt, lens = pager.plan([1, 2])
        assert bt.shape == (2, 4)
        assert (bt[0] >= 0).sum() == 2 and (bt[1] >= 0).sum() == 1
        np.testing.assert_array_equal(lens, [6, 3])

    def test_exhaustion_raises(self):
        pager = PagedKVCache(2, 4, 8)
        pager.allocate(1, 8)
        with pytest.raises(MemoryError):
            pager.allocate(2, 4)


class TestServeEngine:
    def test_end_to_end_batch(self):
        cfg = tiny_cfg()
        params = init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, EngineConfig(
            max_batch=4, max_seq=64, page_size=8, n_pages=64))
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, 64, 12).astype(np.int32),
                        max_new_tokens=6) for _ in range(6)]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 6
        for r in done:
            assert len(r.out_tokens) == 6
        assert eng.pager.utilization == 0.0   # all pages released

    def test_greedy_matches_manual_decode(self):
        from repro.models.transformer import decode_step, prefill

        cfg = tiny_cfg()
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompt = np.arange(8, dtype=np.int32)
        eng = ServeEngine(params, cfg, EngineConfig(
            max_batch=1, max_seq=32, page_size=4, n_pages=32))
        r = Request(prompt=prompt, max_new_tokens=4)
        eng.submit(r)
        done = eng.run()[0]

        logits, cache = prefill(params, cfg, jnp.asarray(prompt[None]),
                                max_seq=32)
        toks = [int(jnp.argmax(logits[0]))]
        pos = len(prompt)
        for _ in range(3):
            logits, cache = decode_step(
                params, cfg, cache, jnp.asarray([toks[-1]]),
                jnp.asarray([pos]))
            toks.append(int(jnp.argmax(logits[0])))
            pos += 1
        assert done.out_tokens == toks

    def test_admission_control_no_deadlock(self):
        cfg = tiny_cfg()
        params = init_params(jax.random.PRNGKey(0), cfg)
        # pool fits ~1.5 requests → must still finish all sequentially
        eng = ServeEngine(params, cfg, EngineConfig(
            max_batch=4, max_seq=32, page_size=4, n_pages=12))
        rng = np.random.default_rng(1)
        for _ in range(3):
            eng.submit(Request(prompt=rng.integers(0, 64, 8).astype(
                np.int32), max_new_tokens=4))
        done = eng.run()
        assert len(done) == 3
