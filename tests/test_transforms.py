"""Axis-transform layer (DESIGN.md §2.5): unit semantics, the
differential harness (transformed extraction ≡ materialized-cube
extraction, byte for byte), and seam canonicalization (period-shifted
cyclic requests share one plan-cache key).

The differential oracle: ``IrregularWeatherCube.materialized()`` builds
the explicitly merged/remapped cube over plain axes with the *same*
flat storage layout, so a request answered through the transform layer
must produce exactly the same offsets — and therefore the same bytes —
as the request answered against the materialized cube (cross-seam
cyclic requests are split into in-period pieces by hand on the
materialized side).
"""

import numpy as np
import pytest

from repro.core import (Box, CyclicAxis, CyclicTransform, MappedTransform,
                        MergedTransform, OrderedAxis, Polygon,
                        PolytopeExtractor, Request, Select, Slicer, Span,
                        TensorDatacube, TransformedDatacube, Union)
from repro.dataplane.weather import (COUNTRIES, IrregularWeatherCube,
                                     gaussian_latitudes)
from repro.serve.extraction import ExtractionService

PERIOD = 360.0


def small_irregular(**kw):
    kw.setdefault("n_dates", 2)
    kw.setdefault("times_per_day", 3)
    kw.setdefault("n_levels", 2)
    kw.setdefault("n_lat", 24)
    kw.setdefault("n_lon", 36)
    return IrregularWeatherCube(**kw)


def split_lon_span(lo: float, hi: float, period: float = PERIOD):
    """Canonical in-period pieces of an unwrapped [lo, hi] lon interval
    (the manual seam split the transform layer performs internally)."""
    if hi - lo >= period:
        return [(0.0, period)]
    k = np.floor(lo / period)
    lo, hi = lo - k * period, hi - k * period
    if hi < period:
        return [(lo, hi)]
    # hi lands on/over the seam: the wrapped tail [0, hi-period] is part
    # of the interval (hi == period includes stored value 0 exactly)
    return [(lo, period), (0.0, hi - period)]


def assert_same_bytes(plan_t, plan_m, data):
    """Byte-identity: same storage offsets ⇒ same bytes."""
    np.testing.assert_array_equal(np.sort(plan_t.offsets),
                                  np.sort(plan_m.offsets))
    np.testing.assert_array_equal(data[np.sort(plan_t.offsets)],
                                  data[np.sort(plan_m.offsets)])


# ---------------------------------------------------------------------------
class TestTransformUnits:
    def test_logical_axis_names_and_periods(self):
        iwc = small_irregular()
        assert iwc.cube.axis_names == ("datetime", "level", "lat", "lon")
        assert iwc.cube.axis_periods() == {"lon": 360.0}

    def test_merged_positions_roundtrip(self):
        t = MergedTransform("dt", ("date", "time"))
        t.logical_axis([OrderedAxis("date", [0.0, 86400.0]),
                        OrderedAxis("time", [0.0, 21600.0, 43200.0])])
        maj, mnr = t.storage_positions(np.arange(6))
        np.testing.assert_array_equal(maj, [0, 0, 0, 1, 1, 1])
        np.testing.assert_array_equal(mnr, [0, 1, 2, 0, 1, 2])

    def test_merged_requires_monotone_combination(self):
        t = MergedTransform("dt", ("date", "time"))
        with pytest.raises(ValueError, match="strictly increasing"):
            # major step (10) smaller than minor span (0..15)
            t.logical_axis([OrderedAxis("date", [0.0, 10.0]),
                            OrderedAxis("time", [0.0, 15.0])])

    def test_mapped_requires_matching_length_and_monotone(self):
        ax = OrderedAxis("row", np.arange(4.0))
        with pytest.raises(ValueError, match="values for"):
            MappedTransform("lat", "row", values=[1.0, 2.0]).logical_axis([ax])
        with pytest.raises(ValueError, match="monotone"):
            MappedTransform("lat", "row",
                            values=[0.0, 2.0, 1.0, 3.0]).logical_axis([ax])

    def test_mapped_func_form(self):
        ax = OrderedAxis("row", np.arange(5.0))
        t = MappedTransform("lat", "row", func=lambda i: 90.0 - 2.0 * i ** 2)
        logical = t.logical_axis([ax])
        assert len(logical) == 5

    def test_storage_axes_must_be_consecutive(self):
        base = TensorDatacube([OrderedAxis(n, np.arange(3.0))
                               for n in ("a", "b", "c")])
        with pytest.raises(ValueError, match="consecutive"):
            TransformedDatacube(base, [MergedTransform("ac", ("a", "c"))])

    def test_offsets_resolve_to_storage(self):
        iwc = small_irregular()
        tdc, base = iwc.cube, iwc.cube.base
        ntime = iwc.times_per_day
        # logical datetime position p ↔ storage (date p//ntime, time p%ntime)
        for p in (0, ntime - 1, ntime, 2 * ntime - 1):
            lo = tdc.base_offset({"datetime": p, "level": 1, "lat": 5,
                                  "lon": 7})
            so = base.base_offset({"date": p // ntime, "time": p % ntime,
                                   "level": 1, "lat_row": 5, "lon": 7})
            assert lo == so

    def test_leaf_offsets_contiguous_for_trailing_axis(self):
        iwc = small_irregular()
        pos = np.arange(10, dtype=np.int64)
        offs = iwc.cube.leaf_offsets(
            {"datetime": 1, "level": 0, "lat": 3}, pos)
        assert np.all(np.diff(offs) == 1)

    def test_merged_leaf_offsets_contiguous_across_minor_boundary(self):
        # merged pair as the deepest axes: logical positions stay
        # byte-contiguous across the date/time storage split
        base = TensorDatacube([OrderedAxis("x", np.arange(2.0)),
                               OrderedAxis("date", [0.0, 86400.0]),
                               OrderedAxis("time", [0.0, 21600.0])])
        tdc = TransformedDatacube(base, [MergedTransform("dt",
                                                         ("date", "time"))])
        offs = tdc.leaf_offsets({"x": 1}, np.arange(4, dtype=np.int64))
        np.testing.assert_array_equal(offs, [4, 5, 6, 7])

    def test_cyclic_nearest_wraps_across_seam(self):
        ax = CyclicAxis("lon", 360.0 * np.arange(16) / 16, period=360.0)
        pos, val = ax.nearest(359.9)          # 0.1° across the seam
        assert (pos, val) == (0, 0.0)
        pos, val = ax.nearest(340.0)          # 2.5° to 337.5, 20° to 360
        assert (pos, val) == (15, 337.5)
        pos, val = ax.nearest(-8.0)           # wraps to 352 → nearest 360≡0
        assert (pos, val) == (0, 0.0)


# ---------------------------------------------------------------------------
class TestDifferentialMaterialized:
    """For any request, extraction through transformed axes is
    byte-identical to extraction against the explicitly materialized
    (unrolled/merged/remapped) datacube."""

    def test_merged_and_mapped_randomized_boxes(self):
        iwc = small_irregular()
        tdc, mat = iwc.cube, iwc.materialized()
        data = iwc.field_data(seed=11)
        dtv = iwc.datetime_values
        rng = np.random.default_rng(42)
        for _ in range(25):
            t0, t1 = np.sort(rng.uniform(dtv[0] - 1e4, dtv[-1] + 1e4, 2))
            la0, la1 = np.sort(rng.uniform(-90, 90, 2))
            lo0 = rng.uniform(0, 300.0)
            lo1 = lo0 + rng.uniform(0, 359.0 - lo0)  # in-period lon
            req = Request([Span("datetime", t0, t1),
                           Box(("lat", "lon"), [la0, lo0], [la1, lo1])])
            plan_t, _ = Slicer(tdc).extract_plan(req)
            plan_m, _ = Slicer(mat).extract_plan(req)
            assert_same_bytes(plan_t, plan_m, data)

    def test_cyclic_randomized_cross_seam_spans(self):
        iwc = small_irregular()
        tdc, mat = iwc.cube, iwc.materialized()
        data = iwc.field_data(seed=12)
        rng = np.random.default_rng(7)
        n_straddle = 0
        for _ in range(30):
            lo = rng.uniform(-720.0, 720.0)
            width = rng.uniform(1.0, 500.0)
            hi = lo + width
            segs = split_lon_span(lo, hi)
            n_straddle += len(segs) > 1
            shapes = [Select("datetime", [0.0]), Select("level", [0.0]),
                      Span("lat", -60.0, 60.0)]
            req_t = Request(shapes + [Span("lon", lo, hi)])
            req_m = Request(shapes + [Union([Span("lon", a, b)
                                             for a, b in segs])])
            plan_t, _ = Slicer(tdc).extract_plan(req_t)
            plan_m, _ = Slicer(mat).extract_plan(req_m)
            assert_same_bytes(plan_t, plan_m, data)
        assert n_straddle > 5          # the sample genuinely hit the seam

    def test_whole_circle_request_reads_every_lon(self):
        iwc = small_irregular()
        plan, _ = Slicer(iwc.cube).extract_plan(Request([
            Select("datetime", [0.0]), Select("level", [0.0]),
            Select("lat", [float(iwc.latitudes[3])]),
            Span("lon", -123.0, -123.0 + 360.0)]))
        assert plan.n_points == iwc.n_lon
        assert plan.n_runs == 1       # one contiguous storage row

    def test_cross_seam_country_polygon(self):
        iwc = small_irregular(n_lat=96, n_lon=192)
        data = iwc.field_data(seed=13)
        pe = PolytopeExtractor(iwc.cube)
        res = pe.extract(iwc.country_request("uk"), data)
        # materialized oracle: the polygon plus its +period copy
        pts = COUNTRIES["uk"]
        req_m = Request([Select("datetime", [0.0]), Select("level", [0.0]),
                         Union([Polygon(("lat", "lon"), pts),
                                Polygon(("lat", "lon"),
                                        pts + [0.0, 360.0])])])
        plan_m, _ = Slicer(iwc.materialized()).extract_plan(req_m)
        assert res.plan.n_points > 0
        assert_same_bytes(res.plan, plan_m, data)
        # the crop genuinely straddles the seam: unwrapped lon values on
        # both sides
        lons = res.plan.coords["lon"]
        assert lons.min() < 0.0 <= lons.max()

    def test_timeseries_across_date_boundary(self):
        iwc = small_irregular()
        data = iwc.field_data(seed=14)
        t0 = float(iwc.time_values[-1]) - 1.0          # last slot of day 0
        t1 = 86400.0 + float(iwc.time_values[0]) + 1.0  # first of day 1
        req = iwc.timeseries_request(float(iwc.latitudes[5]),
                                     float(iwc.lon_values[4]), t0, t1)
        plan_t, _ = Slicer(iwc.cube).extract_plan(req)
        plan_m, _ = Slicer(iwc.materialized()).extract_plan(req)
        assert plan_t.n_points == 2                    # one each side
        assert_same_bytes(plan_t, plan_m, data)

    def test_slice_stats_consistent_on_transformed_cube(self):
        iwc = small_irregular()
        _, stats = Slicer(iwc.cube).extract_plan(
            iwc.seam_box_request(-30.0, 30.0, -40.0, 40.0))
        assert stats.n_slices > 0
        assert sum(stats.n_slices_by_dim.values()) == stats.n_slices


# ---------------------------------------------------------------------------
class TestSeamCanonicalization:
    """Seam-straddling cyclic requests shifted by whole periods share one
    canonical hash — the plan cache hits across the seam."""

    def periods(self):
        return {"lon": PERIOD}

    def test_period_shifted_spans_share_hash(self):
        p = self.periods()
        reqs = [Request([Span("lon", -20.0 + k * PERIOD,
                              20.0 + k * PERIOD)]) for k in (-2, -1, 0, 1, 3)]
        hashes = {r.canonical_hash(periods=p) for r in reqs}
        assert len(hashes) == 1
        # without periods they are distinct spellings
        assert len({r.canonical_hash() for r in reqs}) == len(reqs)

    def test_period_shifted_polygons_share_hash(self):
        p = self.periods()
        pts = COUNTRIES["uk"]
        r0 = Request([Polygon(("lat", "lon"), pts)])
        r1 = Request([Polygon(("lat", "lon"), pts + [0.0, 360.0])])
        r2 = Request([Polygon(("lat", "lon"), pts - [0.0, 720.0])])
        assert (r0.canonical_hash(periods=p) == r1.canonical_hash(periods=p)
                == r2.canonical_hash(periods=p))

    def test_select_values_fold_modulo_period(self):
        p = self.periods()
        assert (Request([Select("lon", [350.0])]).canonical_hash(periods=p)
                == Request([Select("lon", [-10.0])]).canonical_hash(periods=p))
        # non-cyclic axes unaffected
        assert (Request([Select("lat", [350.0])]).canonical_hash(periods=p)
                != Request([Select("lat", [-10.0])]).canonical_hash(periods=p))

    def test_distinct_geometry_still_distinct(self):
        p = self.periods()
        assert (Request([Span("lon", -20.0, 20.0)]).canonical_hash(periods=p)
                != Request([Span("lon", -20.0, 25.0)]).canonical_hash(periods=p))

    def test_plan_cache_hits_across_the_seam(self):
        iwc = small_irregular()
        svc = ExtractionService(iwc.cube)
        base = iwc.seam_box_request(30.0, 60.0, -15.0, 15.0)
        shifted = Request([Select("datetime", [0.0]), Select("level", [0.0]),
                           Box(("lat", "lon"), [30.0, 345.0],
                               [60.0, 375.0])])
        cold = svc.extract(base)
        warm = svc.extract(shifted)
        assert not cold.cached and warm.cached
        assert warm.plan is cold.plan
        assert svc.stats.hits == 1 and svc.stats.misses == 1

    def test_service_plans_match_plain_slicer_on_transformed_cube(self):
        iwc = small_irregular()
        svc = ExtractionService(iwc.cube)
        req = iwc.country_request("uk")
        res = svc.extract(req)
        ref, _ = Slicer(iwc.cube).extract_plan(iwc.country_request("uk"))
        np.testing.assert_array_equal(res.plan.offsets, ref.offsets)


# ---------------------------------------------------------------------------
class TestStandaloneTransformCubes:
    """Transforms compose with arbitrary regular bases, not just the
    weather scenario."""

    def test_mapped_only_cube_matches_plain_irregular_axis(self):
        vals = np.cumsum(np.random.default_rng(3).uniform(0.5, 2.0, 20))
        base = TensorDatacube([OrderedAxis("row", np.arange(20.0)),
                               OrderedAxis("y", np.arange(8.0))])
        tdc = TransformedDatacube(base, [MappedTransform("x", "row",
                                                         values=vals)])
        mat = TensorDatacube([OrderedAxis("x", vals),
                              OrderedAxis("y", np.arange(8.0))])
        req = Request([Box(("x", "y"), [vals[3], 2.0], [vals[11], 6.0])])
        plan_t, _ = Slicer(tdc).extract_plan(req)
        plan_m, _ = Slicer(mat).extract_plan(req)
        np.testing.assert_array_equal(plan_t.offsets, plan_m.offsets)

    def test_descending_mapped_values_keep_storage_order(self):
        # north→south latitudes: logical values descending in storage
        lats = gaussian_latitudes(12)
        assert lats[0] > lats[-1]
        base = TensorDatacube([OrderedAxis("row", np.arange(12.0)),
                               OrderedAxis("y", np.arange(4.0))])
        tdc = TransformedDatacube(base, [MappedTransform("lat", "row",
                                                         values=lats)])
        plan, _ = Slicer(tdc).extract_plan(
            Request([Select("lat", [float(lats[2])]), Span("y", 0.0, 3.0)]))
        # storage row 2 (third from north), full y row
        np.testing.assert_array_equal(plan.offsets, np.arange(8, 12))

    def test_cyclic_transform_equals_cyclic_axis_cube(self):
        vals = 360.0 * np.arange(24) / 24
        base = TensorDatacube([OrderedAxis("t", np.arange(3.0)),
                               OrderedAxis("lon", vals)])
        tdc = TransformedDatacube(base, [CyclicTransform("lon",
                                                         period=360.0)])
        direct = TensorDatacube([OrderedAxis("t", np.arange(3.0)),
                                 CyclicAxis("lon", vals, period=360.0)])
        req = Request([Select("t", [1.0]), Span("lon", -50.0, 20.0)])
        plan_t, _ = Slicer(tdc).extract_plan(req)
        plan_d, _ = Slicer(direct).extract_plan(req)
        np.testing.assert_array_equal(np.sort(plan_t.offsets),
                                      np.sort(plan_d.offsets))
        assert tdc.axis_periods() == direct.axis_periods() == {"lon": 360.0}
