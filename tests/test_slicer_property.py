"""Property tests: the slicer's output must equal brute-force membership.

This is the system's central invariant — the paper's promise is that the
index tree contains *exactly* the datacube points inside the requested
polytope ("ensures that users get back all the points that are contained
in the shape they requested").
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Box, ConvexPolytope, CyclicAxis, Disk, OrderedAxis,
                        Polygon, Request, Select, Slicer, TensorDatacube,
                        Union)
from repro.core.hull import convex_hull_prune

settings.register_profile("repro", deadline=None, max_examples=30)
settings.load_profile("repro")


def brute_force_membership(grid_axes, vertices, tol=1e-9):
    """All grid points inside hull(vertices), via qhull halfspaces."""
    from scipy.spatial import ConvexHull

    hull = ConvexHull(vertices, qhull_options="QJ")
    mesh = np.meshgrid(*grid_axes, indexing="ij")
    pts = np.stack([m.ravel() for m in mesh], -1)
    A, b = hull.equations[:, :-1], hull.equations[:, -1]
    inside = np.all(pts @ A.T + b <= tol, axis=1)
    return {tuple(p) for p in pts[inside]}


def extract_set(plan, axis_names):
    if plan.n_points == 0:
        return set()
    cols = [plan.coords[a] for a in axis_names]
    return set(map(tuple, np.stack(cols, -1)))


@st.composite
def convex_polytope_nd(draw, ndim):
    n_pts = draw(st.integers(ndim + 1, ndim + 5))
    pts = draw(st.lists(
        st.lists(st.floats(-2.0, 12.0, allow_nan=False), min_size=ndim,
                 max_size=ndim),
        min_size=n_pts, max_size=n_pts))
    arr = np.asarray(pts)
    # need full-dimensional hull for the brute-force oracle
    if np.linalg.matrix_rank(arr - arr.mean(0)) < ndim:
        arr = arr + np.eye(ndim + 5)[: len(arr), :ndim] * 7.3
    return arr


@pytest.mark.parametrize("ndim", [2, 3, 4])
@given(data=st.data())
def test_random_convex_polytope_exact(ndim, data):
    verts = data.draw(convex_polytope_nd(ndim))
    names = [f"ax{i}" for i in range(ndim)]
    axes = [OrderedAxis(n, np.arange(10.0)) for n in names]
    cube = TensorDatacube(axes)
    plan, _ = Slicer(cube).extract_plan(
        Request([ConvexPolytope(tuple(names), verts)]))
    got = extract_set(plan, names)
    exp = brute_force_membership([np.arange(10.0)] * ndim,
                                 convex_hull_prune(verts))
    # Tolerance-boundary points may differ by qhull's joggle; allow only
    # boundary-distance discrepancies.
    sym = got ^ exp
    for p in sym:
        from scipy.spatial import ConvexHull
        hull = ConvexHull(convex_hull_prune(verts), qhull_options="QJ")
        A, b = hull.equations[:, :-1], hull.equations[:, -1]
        margin = np.max(np.asarray(p) @ A.T + b)
        assert abs(margin) < 1e-6, (p, margin, "non-boundary mismatch")


@given(lo=st.lists(st.integers(0, 8), min_size=3, max_size=3),
       width=st.lists(st.integers(0, 6), min_size=3, max_size=3))
def test_box_equals_numpy_slicing(lo, width):
    names = ["a", "b", "c"]
    cube = TensorDatacube([OrderedAxis(n, np.arange(12.0)) for n in names])
    lows = np.array(lo, float)
    highs = np.minimum(lows + width, 11.0)
    plan, _ = Slicer(cube).extract_plan(
        Request([Box(names, lows, highs)]))
    data = np.arange(12 ** 3, dtype=np.float64)
    got = np.sort(data[plan.offsets])
    ref = data.reshape(12, 12, 12)[
        int(lows[0]):int(highs[0]) + 1,
        int(lows[1]):int(highs[1]) + 1,
        int(lows[2]):int(highs[2]) + 1].ravel()
    np.testing.assert_array_equal(got, np.sort(ref))


@given(n1=st.integers(1, 6), n2=st.integers(1, 6), n3=st.integers(1, 6))
def test_slice_count_bound(n1, n2, n3):
    """Paper §5.2:  N_slices <= sum_i prod_{j<=i} n_j  (equality for boxes)."""
    names = ["a", "b", "c"]
    cube = TensorDatacube([OrderedAxis(n, np.arange(10.0)) for n in names])
    plan, stats = Slicer(cube).extract_plan(
        Request([Box(names, [0., 0., 0.],
                     [n1 - 1.0, n2 - 1.0, n3 - 1.0])]))
    bound = n1 + n1 * n2 + n1 * n2 * n3
    assert stats.n_slices <= bound
    assert plan.n_points == n1 * n2 * n3


@given(cx=st.floats(-180.0, 540.0), r=st.floats(1.0, 40.0))
def test_cyclic_disk_wraps(cx, r):
    lon = CyclicAxis("lon", np.arange(0.0, 360.0, 10.0), period=360.0)
    lat = OrderedAxis("lat", np.arange(-80.0, 81.0, 10.0))
    cube = TensorDatacube([lat, lon])
    plan, _ = Slicer(cube).extract_plan(
        Request([Disk(("lat", "lon"), (0.0, cx), r, segments=64)]))
    got = {(la, lo % 360.0) for la, lo in
           zip(plan.coords.get("lat", []), plan.coords.get("lon", []))}
    exp = set()
    poly_r_min = r * np.cos(np.pi / 64)  # inscribed polygon radius
    for la in np.arange(-80.0, 81.0, 10.0):
        for lo in np.arange(0.0, 360.0, 10.0):
            d = abs(lo - cx % 360.0)
            d = min(d, 360.0 - d)
            rr = np.hypot(la, d)
            if rr <= poly_r_min - 1e-6:
                exp.add((la, lo))
    # polygonised disk: everything strictly inside the inscribed circle
    # must be found; nothing outside the circumscribed circle may appear.
    assert exp <= got
    for la, lo in got:
        d = abs(lo - cx % 360.0)
        d = min(d, 360.0 - d)
        assert np.hypot(la, d) <= r + 1e-6


@given(seed=st.integers(0, 10_000))
def test_union_merges_duplicates(seed):
    rng = np.random.default_rng(seed)
    names = ["x", "y"]
    cube = TensorDatacube([OrderedAxis(n, np.arange(15.0)) for n in names])
    b1 = rng.uniform(0, 7, 2)
    b2 = rng.uniform(0, 7, 2)
    s1 = Box(names, b1, b1 + rng.uniform(1, 7, 2))
    s2 = Box(names, b2, b2 + rng.uniform(1, 7, 2))
    pu, _ = Slicer(cube).extract_plan(Request([Union([s1, s2])]))
    p1, _ = Slicer(cube).extract_plan(Request([s1]))
    p2, _ = Slicer(cube).extract_plan(Request([s2]))
    assert set(pu.offsets.tolist()) == (set(p1.offsets.tolist()) |
                                        set(p2.offsets.tolist()))
    assert len(pu.offsets) == len(set(pu.offsets.tolist()))


@given(seed=st.integers(0, 10_000))
def test_runs_partition_offsets(seed):
    rng = np.random.default_rng(seed)
    names = ["x", "y", "z"]
    cube = TensorDatacube([OrderedAxis(n, np.arange(8.0)) for n in names])
    verts = rng.uniform(-1, 9, (6, 3))
    plan, _ = Slicer(cube).extract_plan(
        Request([ConvexPolytope(names, verts)]))
    assert plan.run_lengths.sum() == plan.n_points
    rebuilt = np.concatenate([np.arange(s, s + l) for s, l in
                              zip(plan.run_starts, plan.run_lengths)]) \
        if plan.n_runs else np.empty(0, np.int64)
    np.testing.assert_array_equal(np.sort(rebuilt), np.sort(plan.offsets))


def test_polygon_concave_exact():
    cube = TensorDatacube([OrderedAxis(n, np.arange(10.0)) for n in "xy"])
    L = Polygon(("x", "y"),
                np.array([[0, 0], [6, 0], [6, 2], [2, 2], [2, 6], [0, 6]],
                         float))
    plan, _ = Slicer(cube).extract_plan(Request([L]))
    got = set(zip(plan.coords["x"], plan.coords["y"]))
    exp = {(i, j) for i in range(7) for j in range(7)
           if (i <= 6 and j <= 2) or (i <= 2 and j <= 6)}
    assert got == exp
