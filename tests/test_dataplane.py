import numpy as np
import pytest

from repro.dataplane.graph import (CSRGraph, full_graph_batch, minibatch,
                                   molecule_batch, sample_neighbors,
                                   synthetic_graph)
from repro.dataplane.pipeline import Prefetcher
from repro.dataplane.recsys import ClickStream, InteractionStream
from repro.dataplane.tokens import TokenCube
from repro.dataplane.weather import (COUNTRIES, WeatherCube,
                                     paris_newyork_path)
from repro.core import Slicer


class TestTokenCube:
    def test_batch_deterministic(self):
        tc = TokenCube(n_docs=8, doc_len=256)
        b1 = tc.batch(3, 4, 32)
        b2 = tc.batch(3, 4, 32)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        tc = TokenCube(n_docs=4, doc_len=128)
        b = tc.batch(0, 2, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["labels"][:, :-1])

    def test_markov_structure_learnable(self):
        tc = TokenCube(n_docs=4, doc_len=512)
        b = tc.batch(0, 4, 256)
        # ~90% of transitions follow the deterministic permutation
        nxt = tc._next[b["tokens"]]
        agree = (nxt == b["labels"]).mean()
        assert agree > 0.7

    def test_sharded_batches_disjoint_rows(self):
        tc = TokenCube(n_docs=16, doc_len=128)
        b0 = tc.batch(0, 8, 32, shard=0, n_shards=2)
        b1 = tc.batch(0, 8, 32, shard=1, n_shards=2)
        assert b0["tokens"].shape[0] == 4
        assert not np.array_equal(b0["tokens"], b1["tokens"])


class TestGraphPlane:
    def test_synthetic_graph_sizes(self):
        g = synthetic_graph(500, 8, 16, 5)
        assert g.n_nodes == 500
        assert g.indptr[-1] == g.n_edges
        assert g.node_feat.shape == (500, 16)

    def test_sampler_fanout_bound(self):
        g = synthetic_graph(300, 10, 8, 3)
        rng = np.random.default_rng(0)
        seeds = np.arange(16)
        nodes, ei = sample_neighbors(g, seeds, [5, 3], rng)
        assert ei.shape[1] <= 16 * 5 + 16 * 5 * 3
        assert ei.max() < len(nodes)

    def test_minibatch_padded_shapes(self):
        g = synthetic_graph(300, 10, 8, 3)
        b = minibatch(g, 32, [5, 3], pad_nodes=512, pad_edges=1024)
        assert b["node_feat"].shape == (512, 8)
        assert b["edge_index"].shape == (2, 1024)
        assert b["label_mask"].sum() == 32

    def test_molecule_energy_invariant(self):
        b1 = molecule_batch(4, 10, 20, 8, step=5)
        b2 = molecule_batch(4, 10, 20, 8, step=5)
        np.testing.assert_array_equal(b1["energy"], b2["energy"])
        assert np.isfinite(b1["energy"]).all()


class TestClickStream:
    def test_labels_correlate_with_features(self):
        cs = ClickStream(rows=10_000, seed=0)
        b = cs.batch(0, 8192)
        # the hidden model must make labels predictable from dense feats
        w = np.linalg.lstsq(b["dense"], b["labels"] - 0.5,
                            rcond=None)[0]
        pred = b["dense"] @ w > 0
        acc = (pred == (b["labels"] > 0.5)).mean()
        assert acc > 0.55

    def test_zipf_ids_skewed(self):
        cs = ClickStream(rows=10_000)
        b = cs.batch(0, 4096)
        assert (b["bags"] == 0).mean() > 0.2   # head-heavy

    def test_interactions(self):
        s = InteractionStream(n_users=1000, n_items=1000)
        p = s.pairs(0, 64)
        assert p["user_ids"].shape == (64,)
        q = s.sequences(0, 8, 32, mask_token=1000)
        assert ((q["items"] == 1000) == (q["mask"] > 0)).all()


class TestWeatherPlane:
    def test_country_polygons_closed_and_sane(self):
        for name, poly in COUNTRIES.items():
            assert poly.shape[1] == 2
            assert len(poly) >= 9
            assert (np.abs(poly[:, 0]) <= 90).all()

    def test_country_vs_bbox_reduction(self):
        wc = WeatherCube(n=64, n_times=2, n_levels=3)
        from repro.core import BoundingBoxExtractor, PolytopeExtractor

        req = wc.country_request("norway")
        poly_plan, _ = PolytopeExtractor(wc.cube).plan(req)
        box_plan = BoundingBoxExtractor(wc.cube).plan(req)
        # Norway is paper Table 1's 6× case — elongated vs its bbox
        assert box_plan.n_points > 2.5 * poly_plan.n_points

    def test_timeseries_points(self):
        wc = WeatherCube(n=32, n_times=8, n_levels=3)
        req = wc.timeseries_request(51.5, 0.0, 0.0, 7 * 3600.0)
        plan, _ = Slicer(wc.cube).extract_plan(req)
        assert plan.n_points == 8      # one point per timestep

    def test_flight_path_extracts_tube(self):
        wc = WeatherCube(n=32, n_times=4, n_levels=5)
        req = wc.flight_path_request(paris_newyork_path(wc), width=6.0)
        plan, _ = Slicer(wc.cube).extract_plan(req)
        assert plan.n_points > 0


class TestPrefetcher:
    def test_orders_and_prefetches(self):
        pf = Prefetcher(lambda s: {"x": np.full(2, s)}, depth=2)
        out = [next(pf) for _ in range(5)]
        pf.close()
        assert [s for s, _ in out] == list(range(5))
        np.testing.assert_array_equal(out[3][1]["x"], 3.0)

    def test_error_propagates(self):
        def bad(step):
            if step == 2:
                raise ValueError("boom")
            return step

        pf = Prefetcher(bad, depth=1)
        assert next(pf)[0] == 0
        assert next(pf)[0] == 1
        with pytest.raises(ValueError):
            next(pf)
            next(pf)
        pf.close()
