"""Fault-tolerance: crash/restore determinism, stragglers, elasticity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.fault import FaultConfig, StragglerMonitor, Supervisor
from repro.train.optimizer import OptimizerConfig
from repro.train.train_state import init_train_state, make_train_step

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


def _setup(tmp_path, ckpt_every=5):
    cfg = OptimizerConfig(kind="adamw", lr=0.05, weight_decay=0.0,
                          warmup_steps=0, total_steps=1000)

    def loss_fn(params, batch):
        return jnp.mean(jnp.square(params["w"] - batch)), {}

    params = {"w": jnp.ones((4, 4)) * 3.0}
    state = init_train_state(params, cfg)
    step = jax.jit(make_train_step(loss_fn, cfg))

    def data_fn(step_idx):   # step-addressable → deterministic replay
        return jnp.full((4, 4), float(step_idx % 3))

    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                       max_restarts=10, async_ckpt=False)
    return fcfg, step, data_fn, state


class TestSupervisor:
    def test_no_fault_runs_to_completion(self, tmp_path):
        fcfg, step, data_fn, state = _setup(tmp_path)
        sup = Supervisor(fcfg, step, data_fn)
        out = sup.run(state, 12)
        assert latest_step(tmp_path) == 9
        assert np.isfinite(np.asarray(out["params"]["w"])).all()

    def test_crash_restore_equals_uninterrupted(self, tmp_path):
        fcfg, step, data_fn, state = _setup(tmp_path)
        # clean run
        clean = Supervisor(fcfg, step, data_fn).run(state, 20)

        # crashing run in a fresh dir
        fcfg2 = FaultConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5,
                            max_restarts=10, async_ckpt=False)
        crashed = {"done": False}

        def injector(s):
            if s == 12 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        out = Supervisor(fcfg2, step, data_fn,
                         fault_injector=injector).run(state, 20)
        np.testing.assert_allclose(np.asarray(out["params"]["w"]),
                                   np.asarray(clean["params"]["w"]),
                                   rtol=1e-6)

    def test_exhausted_restart_budget_raises(self, tmp_path):
        fcfg, step, data_fn, state = _setup(tmp_path)
        fcfg.max_restarts = 2

        def injector(s):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            Supervisor(fcfg, step, data_fn,
                       fault_injector=injector).run(state, 5)


class TestStraggler:
    def test_detects_outlier(self):
        mon = StragglerMonitor(factor=3.0)
        for _ in range(10):
            mon.record(0.1)
        assert mon.is_straggler(1.0)
        assert not mon.is_straggler(0.15)

    def test_needs_warmup(self):
        mon = StragglerMonitor()
        assert not mon.is_straggler(100.0)   # no baseline yet

    def test_skip_and_repair_records(self):
        mon = StragglerMonitor()
        mon.skip_and_repair(17)
        assert mon.skipped_steps == [17]


class TestElasticRestore:
    def test_restore_into_different_replication(self, tmp_path):
        """Save, then restore into a fresh (differently laid out)
        target — the cross-mesh path on one host."""
        state = {"w": jnp.arange(64.0).reshape(8, 8),
                 "step": jnp.asarray(3)}
        save_checkpoint(tmp_path, 3, state)
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        sh = jax.tree.map(
            lambda x: jax.sharding.SingleDeviceSharding(
                jax.devices()[0]), state)
        out = restore_checkpoint(tmp_path, 3, target, sh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(state["w"]))
