"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against
the pure-jnp oracle (interpret mode executes the Pallas kernel body)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gather import kernel as gk, ref as gr
from repro.kernels.paged_attn import kernel as pk, ref as pr
from repro.kernels.segment import kernel as sk, ref as sr
from repro.kernels.slice import kernel as slk, ops as slo, ref as slr

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


class TestGatherRows:
    @pytest.mark.parametrize("n,d,m", [(16, 8, 4), (128, 64, 100),
                                       (64, 128, 7), (33, 16, 33)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, d, m, dtype):
        rng = np.random.default_rng(n * d + m)
        table = jnp.asarray(rng.normal(size=(n, d)), dtype=dtype)
        idx = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
        np.testing.assert_allclose(
            np.asarray(gk.gather_rows(table, idx), np.float32),
            np.asarray(gr.gather_rows(table, idx), np.float32), **tol(dtype))

    def test_repeated_indices(self):
        table = jnp.arange(40.0).reshape(10, 4)
        idx = jnp.asarray([3, 3, 3, 0], dtype=jnp.int32)
        out = gk.gather_rows(table, idx)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))


class TestGatherBag:
    @pytest.mark.parametrize("n,d,b,l", [(32, 8, 4, 3), (64, 32, 16, 8),
                                         (128, 16, 5, 1)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, n, d, b, l, dtype):
        rng = np.random.default_rng(n + d + b + l)
        table = jnp.asarray(rng.normal(size=(n, d)), dtype=dtype)
        bags = jnp.asarray(rng.integers(-1, n, (b, l)).astype(np.int32))
        np.testing.assert_allclose(
            np.asarray(gk.gather_rows_bag(table, bags)),
            np.asarray(gr.gather_rows_bag(table, bags)), **tol(dtype))

    def test_all_padding_row_is_zero(self):
        table = jnp.ones((8, 4))
        bags = jnp.full((2, 3), -1, dtype=jnp.int32)
        out = gk.gather_rows_bag(table, bags)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((2, 4)))


class TestSliceBatch:
    @pytest.mark.parametrize("p,v,d,k", [(4, 6, 3, 0), (10, 8, 4, 2),
                                         (1, 4, 2, 1), (9, 12, 5, 4)])
    def test_matches_ref(self, p, v, d, k):
        rng = np.random.default_rng(p * v + d + k)
        verts = jnp.asarray(rng.uniform(0, 10, (p, v, d)).astype(np.float32))
        nvalid = rng.integers(2, v + 1, p)
        valid = jnp.asarray(np.arange(v)[None, :] < nvalid[:, None])
        planes = jnp.asarray(rng.uniform(0, 10, p).astype(np.float32))
        ok, mk = slk.slice_batch(verts, valid, planes, k=k)
        orf, mrf = slr.slice_batch(verts, valid, planes, k=k)
        np.testing.assert_array_equal(np.asarray(mk), np.asarray(mrf))
        np.testing.assert_allclose(np.asarray(ok), np.asarray(orf),
                                   rtol=1e-5, atol=1e-5)

    def test_agrees_with_host_slicer(self):
        from repro.core.geometry import Polytope, slice_vertices
        from repro.core.hull import convex_hull_prune

        rng = np.random.default_rng(7)
        polys = [Polytope(("x", "y", "z"), rng.uniform(0, 10, (6, 3)))
                 for _ in range(12)]
        verts, valid = slo.pack_polytopes(polys, v_max=8)
        planes = jnp.asarray(rng.uniform(3, 7, 12).astype(np.float32))
        out, mask = slk.slice_batch(verts, valid, planes, k=1)
        subs = slo.unpack_sliced(out, mask, ("x", "y", "z"), k=1)
        for poly, sub, c in zip(polys, subs, np.asarray(planes)):
            host = slice_vertices(poly.points, 1, float(c), tol=1e-6)
            if host is None:
                continue
            hp = convex_hull_prune(host)
            assert sub is not None
            a = np.asarray(sorted(map(tuple, np.round(hp, 3))))
            b = np.asarray(sorted(map(tuple, np.round(sub.points, 3))))
            assert len(a) == len(b)
            np.testing.assert_allclose(a, b, atol=2e-3)


class TestPagedAttention:
    @pytest.mark.parametrize("b,h,kvh,dh,ps,pmax",
                             [(2, 4, 4, 8, 4, 3),    # MHA
                              (3, 8, 2, 16, 4, 6),   # GQA
                              (1, 8, 1, 32, 8, 4)])  # MQA
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, kvh, dh, ps, pmax, dtype):
        rng = np.random.default_rng(b * h + dh)
        n_pages = b * pmax + 3
        q = jnp.asarray(rng.normal(size=(b, h, dh)), dtype=dtype)
        kp = jnp.asarray(rng.normal(size=(n_pages, kvh, ps, dh)),
                         dtype=dtype)
        vp = jnp.asarray(rng.normal(size=(n_pages, kvh, ps, dh)),
                         dtype=dtype)
        lens = rng.integers(1, ps * pmax + 1, b).astype(np.int32)
        bt = np.full((b, pmax), -1, np.int32)
        free = list(rng.permutation(n_pages))
        for i in range(b):
            need = int(np.ceil(lens[i] / ps))
            for j in range(need):
                bt[i, j] = free.pop()
        out_k = pk.paged_decode_attention(q, kp, vp, jnp.asarray(bt),
                                          jnp.asarray(lens))
        out_r = pr.paged_decode_attention(q, kp, vp, jnp.asarray(bt),
                                          jnp.asarray(lens))
        np.testing.assert_allclose(np.asarray(out_k, np.float32),
                                   np.asarray(out_r, np.float32),
                                   **tol(dtype))

    def test_reads_only_planned_pages(self):
        """Poisoning un-planned pages must not change the output — the
        kernel provably reads only the extraction plan's bytes."""
        rng = np.random.default_rng(0)
        b, h, kvh, dh, ps, pmax, n_pages = 1, 4, 2, 8, 4, 2, 8
        q = jnp.asarray(rng.normal(size=(b, h, dh)).astype(np.float32))
        kp = rng.normal(size=(n_pages, kvh, ps, dh)).astype(np.float32)
        vp = rng.normal(size=(n_pages, kvh, ps, dh)).astype(np.float32)
        bt = jnp.asarray([[2, 5]], dtype=jnp.int32)
        lens = jnp.asarray([7], dtype=jnp.int32)
        out1 = pk.paged_decode_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                                         bt, lens)
        kp2, vp2 = kp.copy(), vp.copy()
        for pg in range(n_pages):
            if pg not in (2, 5):
                kp2[pg] = 1e9
                vp2[pg] = -1e9
        out2 = pk.paged_decode_attention(q, jnp.asarray(kp2),
                                         jnp.asarray(vp2), bt, lens)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


class TestSegmentSum:
    @pytest.mark.parametrize("e,d,s", [(100, 8, 10), (1000, 16, 40),
                                       (256, 128, 4), (7, 4, 3)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, e, d, s, dtype):
        rng = np.random.default_rng(e + d + s)
        msg = jnp.asarray(rng.normal(size=(e, d)), dtype=dtype)
        seg = jnp.asarray(rng.integers(-1, s, e).astype(np.int32))
        np.testing.assert_allclose(
            np.asarray(sk.segment_sum(msg, seg, s)),
            np.asarray(sr.segment_sum(msg, seg, s)), rtol=1e-4, atol=1e-4)

    def test_empty_segments_zero(self):
        msg = jnp.ones((4, 2))
        seg = jnp.asarray([0, 0, 0, 0], dtype=jnp.int32)
        out = sk.segment_sum(msg, seg, 3)
        np.testing.assert_array_equal(np.asarray(out[1:]), np.zeros((2, 2)))
