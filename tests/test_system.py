"""End-to-end behaviour tests: the paper's extraction engine driving
real training/serving loops (integration across all layers)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BoundingBoxExtractor, PolytopeExtractor, Request,
                        Slicer)
from repro.dataplane.tokens import TokenCube
from repro.dataplane.weather import WeatherCube, paris_newyork_path
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train.fault import FaultConfig, Supervisor
from repro.train.optimizer import OptimizerConfig
from repro.train.train_state import init_train_state, make_train_step


def test_polytope_pipeline_trains_lm(tmp_path):
    """Full loop: token batches are planned + gathered by the Polytope
    engine, fed through the fault-tolerant supervisor, and the LM
    learns the corpus' Markov structure."""
    tc = TokenCube(vocab=64, n_docs=8, doc_len=512, seed=1)
    cfg = TransformerConfig(name="sys", vocab=64, d_model=64,
                            n_layers=2, n_heads=4, n_kv_heads=2,
                            d_head=16, d_ff=128, q_chunk=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(kind="adamw", lr=3e-3, warmup_steps=10,
                           total_steps=2000)
    state = init_train_state(params, ocfg)
    step = jax.jit(make_train_step(
        lambda p, b: loss_fn(p, cfg, b["tokens"], b["labels"]), ocfg))

    def data_fn(s):
        b = tc.batch(s, 8, 64)
        return {k: jnp.asarray(v) for k, v in b.items()}

    losses = []
    sup = Supervisor(FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=25,
                                 async_ckpt=False),
                     step, data_fn)
    state = sup.run(state, 60,
                    on_metrics=lambda s, m: losses.append(
                        float(m["loss"])))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.7, losses


def test_extraction_reduction_on_weather_system():
    """System-level reproduction of the paper's headline: Polytope
    reads strictly fewer bytes than the bbox baseline on non-orthogonal
    requests, and identical bytes on orthogonal ones (Table 1 rows
    1-3 vs 4-7)."""
    wc = WeatherCube(n=64, n_times=8, n_levels=10)
    pe = PolytopeExtractor(wc.cube)
    bb = BoundingBoxExtractor(wc.cube)

    # orthogonal: time-series → equal
    req = wc.timeseries_request(51.5, 0.0, 0.0, 7 * 3600.0)
    assert pe.plan(req)[0].nbytes == bb.plan(req).nbytes

    # non-orthogonal: country + flight path → strictly smaller
    for req in [wc.country_request("france"),
                wc.flight_path_request(paris_newyork_path(wc),
                                       width=4.0)]:
        p, b = pe.plan(req)[0].nbytes, bb.plan(req).nbytes
        assert 0 < p < b


def test_extracted_values_match_ground_truth():
    """The bytes returned are the right bytes: gathered values equal a
    direct lookup of the synthetic field at the plan's coordinates."""
    wc = WeatherCube(n=32, n_times=4, n_levels=5)
    data = wc.field_data(seed=3)
    pe = PolytopeExtractor(wc.cube)
    res = pe.extract(wc.country_request("germany", time=3600.0 * 2,
                                        level=3.0), data)
    assert res.values is not None and len(res.values) > 0
    np.testing.assert_array_equal(res.values, data[res.plan.offsets])
    # all extracted latitudes actually fall inside Germany's bbox
    from repro.dataplane.weather import COUNTRIES

    poly = COUNTRIES["germany"]
    assert res.plan.coords["lat"].min() >= poly[:, 0].min() - 1e-9
    assert res.plan.coords["lat"].max() <= poly[:, 0].max() + 1e-9


def test_slice_count_scaling_matches_paper_bound():
    """§5.2: N_slices ≤ Σ_i Π_{j≤i} n_j, equality for boxes, and the
    1-D layer dominates (n1 ≤ n1·n2) — measured on the O-grid cube."""
    from repro.core import Box, Select

    wc = WeatherCube(n=64, n_times=4, n_levels=5)
    req = Request([Select("time", [0.0]), Select("level", [0.0]),
                   Box(("lat", "lon"), [30.0, 10.0], [60.0, 60.0])])
    plan, stats = Slicer(wc.cube).extract_plan(req)
    by_dim = stats.n_slices_by_dim
    assert by_dim.get(1, 0) >= by_dim.get(2, 0)       # 1-D dominates
    assert by_dim.get(1, 0) == plan.n_points          # 1 slice / point
