"""Fast-path parity: the vector-leaf and shared-box shortcuts in the
slicer are pure optimisations — ``Slicer(cube, fast_paths=False)`` runs
the per-index reference expansion of Algorithm 1, and both executors
must emit identical plans and identical §5.2 slice accounting, on plain
and on transformed (merged/mapped/cyclic) datacubes alike."""

import numpy as np

from repro.core import (Box, ConvexPolytope, Disk, OrderedAxis, Request,
                        Select, Slicer, Span, TensorDatacube, Union)
from repro.dataplane.weather import IrregularWeatherCube


def grid_cube(n=10, names=("a", "b", "c")):
    return TensorDatacube(
        [OrderedAxis(nm, np.arange(float(n))) for nm in names])


def assert_parity(cube, request):
    plan_f, stats_f = Slicer(cube).extract_plan(request)
    plan_r, stats_r = Slicer(cube, fast_paths=False).extract_plan(request)
    np.testing.assert_array_equal(np.sort(plan_f.offsets),
                                  np.sort(plan_r.offsets))
    # identical accounting: the shortcuts must report what the per-index
    # path would have counted, not what they skipped
    assert stats_f.n_slices == stats_r.n_slices
    assert stats_f.n_slices_by_dim == stats_r.n_slices_by_dim
    # §5.2 bound holds on both executors
    for stats in (stats_f, stats_r):
        assert sum(stats.n_slices_by_dim.values()) == stats.n_slices
    return plan_f, stats_f


class TestFastPathParity:
    def test_box_hits_both_shortcuts(self):
        # nd box → shared-box path; its leaf rows → vector-leaf path
        assert_parity(grid_cube(),
                      Request([Box(("a", "b", "c"), [1, 1, 1], [5, 6, 4])]))

    def test_polytope_leaf_rows(self):
        verts = np.array([[0, 0, 0], [8, 0, 0], [0, 8, 0], [0, 0, 8]],
                         float)
        assert_parity(grid_cube(),
                      Request([ConvexPolytope(("a", "b", "c"), verts)]))

    def test_select_plus_disk(self):
        assert_parity(grid_cube(),
                      Request([Select("a", [2.0, 5.0]),
                               Disk(("b", "c"), (4.0, 4.0), 2.5)]))

    def test_union_of_overlapping_boxes(self):
        assert_parity(grid_cube(), Request([
            Union([Box(("a", "b"), [0, 0], [4, 4]),
                   Box(("a", "b"), [3, 3], [7, 7])]),
            Span("c", 1.0, 3.0)]))

    def test_randomized_requests(self):
        rng = np.random.default_rng(42)
        cube = grid_cube()
        for _ in range(20):
            lo = rng.uniform(0, 5, size=3)
            hi = lo + rng.uniform(0.5, 4.5, size=3)
            req = Request([Box(("a", "b", "c"), list(lo), list(hi))])
            plan, stats = assert_parity(cube, req)
            # §5.2: box slice count equals the exact bound Σ_i Π_{j≤i} n_j
            ns = [len(cube.axis(nm, {}).indices_in_range(l, h)[0])
                  for nm, l, h in zip("abc", lo, hi)]
            assert stats.n_slices == ns[0] + ns[0] * ns[1] + \
                ns[0] * ns[1] * ns[2]
            assert plan.n_points == ns[0] * ns[1] * ns[2]

    def test_randomized_polytopes(self):
        rng = np.random.default_rng(43)
        cube = grid_cube()
        for _ in range(10):
            verts = rng.uniform(0, 9, size=(5, 2))
            assert_parity(cube, Request([
                Select("a", [float(rng.integers(0, 10))]),
                ConvexPolytope(("b", "c"), verts)]))


class TestFastPathParityTransformed:
    """Same parity contract through the axis-transform layer
    (DESIGN.md §2.5): logical-coordinate planning, storage-coordinate
    offsets."""

    def setup_method(self):
        self.iwc = IrregularWeatherCube(n_dates=2, times_per_day=3,
                                        n_levels=2, n_lat=16, n_lon=24)

    def test_cross_seam_box(self):
        assert_parity(self.iwc.cube,
                      self.iwc.seam_box_request(20.0, 70.0, -30.0, 30.0))

    def test_country_polygon(self):
        assert_parity(self.iwc.cube, self.iwc.country_request("uk"))

    def test_timeseries_across_midnight(self):
        assert_parity(self.iwc.cube,
                      self.iwc.timeseries_request(51.5, 0.0, 0.0,
                                                  86400.0 + 43200.0))

    def test_randomized_cyclic_spans(self):
        rng = np.random.default_rng(44)
        for _ in range(15):
            lo = rng.uniform(-400, 400)
            req = Request([Select("datetime", [0.0]),
                           Select("level", [0.0]),
                           Span("lat", -60.0, 60.0),
                           Span("lon", lo, lo + rng.uniform(0, 400))])
            assert_parity(self.iwc.cube, req)
