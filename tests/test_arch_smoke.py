"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes and no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch

pytestmark = pytest.mark.slow  # JAX-compile heavy; fast lane runs -m 'not slow'


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    arch = get_arch(arch_id)
    smoke = arch.smoke()
    state, batch, step = smoke["state"], smoke["batch"], smoke["step"]
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_id}: non-finite loss {loss}"
    # params changed and stayed finite
    changed = False
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(state2["params"])):
        assert bool(jnp.isfinite(b).all()), f"{arch_id}: NaN params"
        changed = changed or not np.array_equal(np.asarray(a),
                                                np.asarray(b))
    assert changed, f"{arch_id}: step did not update params"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_loss_decreases(arch_id):
    """A few steps on a fixed batch must reduce the loss."""
    arch = get_arch(arch_id)
    smoke = arch.smoke()
    state, batch, step = smoke["state"], smoke["batch"], smoke["step"]
    step = jax.jit(step)
    first = None
    for _ in range(5):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first, \
        f"{arch_id}: loss {first} → {float(metrics['loss'])}"


@pytest.mark.parametrize("arch_id", ["glm4-9b", "deepseek-v3-671b"])
def test_smoke_forward_shapes(arch_id):
    arch = get_arch(arch_id)
    smoke = arch.smoke()
    if "forward" not in smoke:
        pytest.skip("no forward fn")
    logits, aux = smoke["forward"]()
    assert logits.ndim == 3
    assert bool(jnp.isfinite(logits).all())


def test_all_cells_enumerate():
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40, f"expected 40 cells, got {len(cells)}"
