"""Plan-cache semantics: canonical hashing, LRU behaviour, and the
extraction service (DESIGN.md §4)."""

import numpy as np
import pytest

from repro.core import (Box, ConvexPolytope, Disk, OrderedAxis, Request,
                        Select, Slicer, Span, TensorDatacube, Union)
from repro.dataplane.pipeline import CachedExtractionSource, Prefetcher
from repro.serve.extraction import ExtractionService, PlanCache


def small_cube(n=12, names=("a", "b", "c")):
    return TensorDatacube(
        [OrderedAxis(nm, np.arange(float(n))) for nm in names])


def tri_request(shift=0.0):
    verts = np.array([[0.0, 0.0], [8.0, 0.0], [0.0, 8.0]]) + shift
    return Request([ConvexPolytope(("a", "b"), verts),
                    Select("c", [1.0, 3.0])])


class TestCanonicalHash:
    def test_permuted_union_members_collide(self):
        s1 = Box(("a", "b"), [0, 0], [3, 3])
        s2 = Disk(("a", "b"), (6.0, 6.0), 2.0)
        r_ab = Request([Union([s1, s2])])
        r_ba = Request([Union([s2, s1])])
        assert r_ab.canonical_hash() == r_ba.canonical_hash()
        assert r_ab.canonical_form() == r_ba.canonical_form()

    def test_permuted_select_values_collide(self):
        r1 = Request([Select("c", [3.0, 1.0, 2.0])])
        r2 = Request([Select("c", [1.0, 2.0, 3.0])])
        r3 = Request([Select("c", [1.0]), Select("c", [3.0, 2.0])])
        assert r1.canonical_hash() == r2.canonical_hash()
        assert r1.canonical_hash() == r3.canonical_hash()

    def test_duplicate_members_and_values_collide(self):
        s = Box(("a", "b"), [0, 0], [3, 3])
        assert (Request([Union([s, s]), Select("c", [1, 1])])
                .canonical_hash() ==
                Request([s, Select("c", [1])]).canonical_hash())

    def test_tolerance_quantized_vertices_collide(self):
        base = np.array([[0.0, 0.0], [8.0, 0.0], [0.0, 8.0]])
        jitter = base + 1e-13          # far below the quantum
        assert (Request([ConvexPolytope(("a", "b"), base)]).canonical_hash()
                == Request([ConvexPolytope(("a", "b"),
                                           jitter)]).canonical_hash())

    def test_geometrically_distinct_differ(self):
        assert (tri_request(0.0).canonical_hash()
                != tri_request(1.0).canonical_hash())
        assert (Request([Span("a", 0, 5)]).canonical_hash()
                != Request([Span("b", 0, 5)]).canonical_hash())
        assert (Request([Select("c", [1.0])]).canonical_hash()
                != Request([Select("c", [2.0])]).canonical_hash())

    def test_box_and_equivalent_polytope_collide(self):
        # is_box is an execution detail, not geometry — same plan bytes.
        box = Box(("a", "b"), [1, 2], [4, 5])
        verts = np.array([[1, 2], [1, 5], [4, 2], [4, 5]], float)
        assert (Request([box]).canonical_hash()
                == Request([ConvexPolytope(("a", "b"),
                                           verts)]).canonical_hash())

    def test_hash_is_stable_content_hash(self):
        # Process-independent: a fixed request pins its digest format.
        h = Request([Span("a", 0.0, 2.0)]).canonical_hash()
        assert isinstance(h, str) and len(h) == 64
        assert h == Request([Span("a", 0.0, 2.0)]).canonical_hash()


class TestPlanCacheLRU:
    def test_eviction_order_is_lru(self):
        pc = PlanCache(capacity=2)
        pc.put("k1", "p1")
        pc.put("k2", "p2")
        assert pc.get("k1") == "p1"        # k1 becomes MRU
        pc.put("k3", "p3")                 # evicts k2, not k1
        assert "k2" not in pc
        assert "k1" in pc and "k3" in pc
        assert pc.stats.evictions == 1

    def test_counters(self):
        pc = PlanCache(capacity=4)
        assert pc.get("missing") is None
        pc.put("k", "p")
        assert pc.get("k") == "p"
        assert pc.stats.hits == 1
        assert pc.stats.misses == 1
        assert pc.stats.hit_rate == 0.5


class TestExtractionService:
    def test_repeat_request_served_from_cache(self):
        svc = ExtractionService(small_cube())
        cold = svc.extract(tri_request())
        assert not cold.cached
        assert cold.stats is not None            # cold plan ran Alg. 1
        warm = svc.extract(tri_request())
        assert warm.cached
        assert warm.stats is None                # no new SliceStats
        assert svc.stats.hits == 1 and svc.stats.misses == 1
        # byte-identical offsets: the exact plan object is shared
        assert warm.plan is cold.plan
        np.testing.assert_array_equal(warm.plan.offsets, cold.plan.offsets)

    def test_hit_offsets_match_independent_cold_plan(self):
        cube = small_cube()
        svc = ExtractionService(cube)
        svc.extract(tri_request())
        hit = svc.extract(tri_request())
        ref, _ = Slicer(cube).extract_plan(tri_request())
        np.testing.assert_array_equal(hit.plan.offsets, ref.offsets)

    def test_batch_dedupes_and_shares_reads(self):
        cube = small_cube()
        data = np.arange(cube.n_elements, dtype=np.float64)
        svc = ExtractionService(cube)
        reqs = [tri_request(), tri_request(), tri_request(1.0)]
        results = svc.submit_batch(reqs, data)
        assert svc.stats.misses == 2             # two distinct geometries
        assert svc.stats.batch_dedup == 1        # in-batch duplicate
        assert results[1].plan is results[0].plan
        for res in results:
            np.testing.assert_array_equal(res.values,
                                          data[res.plan.offsets])
        # overlapping requests read shared bytes once
        assert svc.stats.bytes_read < svc.stats.bytes_requested
        assert svc.stats.sharing_factor > 1.0

    def test_equivalent_permuted_batch_members_hit(self):
        svc = ExtractionService(small_cube())
        s1 = Box(("a", "b"), [0, 0], [3, 3])
        s2 = Disk(("a", "b"), (6.0, 6.0), 2.0)
        svc.extract(Request([Union([s1, s2])]))
        res = svc.extract(Request([Union([s2, s1])]))
        assert res.cached

    def test_lru_eviction_end_to_end(self):
        svc = ExtractionService(small_cube(), capacity=2)
        r1, r2, r3 = tri_request(0.0), tri_request(1.0), tri_request(2.0)
        svc.extract(r1)
        svc.extract(r2)
        svc.extract(r1)                  # r1 MRU → order [r2, r1]
        svc.extract(r3)                  # evicts LRU r2 → [r1, r3]
        assert svc.stats.evictions == 1
        assert svc.extract(r1).cached
        assert svc.extract(r3).cached
        assert not svc.extract(r2).cached    # r2 was evicted

    def test_empty_plan_values(self):
        cube = small_cube()
        data = np.arange(cube.n_elements, dtype=np.float64)
        svc = ExtractionService(cube)
        # box entirely outside the grid → empty plan
        res = svc.extract(Request([Box(("a", "b"), [50, 50], [60, 60])]),
                          data)
        assert res.plan.n_points == 0
        assert len(res.values) == 0


class TestPrefetcherReusesPlans:
    def test_plans_cached_across_steps(self):
        cube = small_cube()
        data = np.arange(cube.n_elements, dtype=np.float64)
        svc = ExtractionService(cube)
        # recurring production mix: step alternates between two crops
        crops = [tri_request(0.0), tri_request(2.0)]
        src = CachedExtractionSource(svc, lambda s: crops[s % 2], data)
        pf = Prefetcher(src, depth=2)
        out = [next(pf) for _ in range(6)]
        pf.close()
        assert [s for s, _ in out] == list(range(6))
        assert svc.stats.misses == 2             # one cold plan per crop
        assert svc.stats.hits >= 4               # later steps all hit
        ref, _ = Slicer(cube).extract_plan(crops[0])
        np.testing.assert_array_equal(out[4][1].values, data[ref.offsets])
