"""Plan-cache semantics: canonical hashing, LRU behaviour, and the
extraction service (DESIGN.md §4).

The canonical-hash invariants are also checked property-style at the
bottom of this module: a seeded-rng class that always runs, and a
hypothesis class that deepens the search when hypothesis is installed
(skipped cleanly otherwise — the container does not ship it)."""

import numpy as np
import pytest

from repro.core import (Box, ConvexPolytope, Disk, OrderedAxis, Request,
                        Select, Slicer, Span, TensorDatacube, Union)
from repro.dataplane.pipeline import CachedExtractionSource, Prefetcher
from repro.serve.extraction import ExtractionService, PlanCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def small_cube(n=12, names=("a", "b", "c")):
    return TensorDatacube(
        [OrderedAxis(nm, np.arange(float(n))) for nm in names])


def tri_request(shift=0.0):
    verts = np.array([[0.0, 0.0], [8.0, 0.0], [0.0, 8.0]]) + shift
    return Request([ConvexPolytope(("a", "b"), verts),
                    Select("c", [1.0, 3.0])])


class TestCanonicalHash:
    def test_permuted_union_members_collide(self):
        s1 = Box(("a", "b"), [0, 0], [3, 3])
        s2 = Disk(("a", "b"), (6.0, 6.0), 2.0)
        r_ab = Request([Union([s1, s2])])
        r_ba = Request([Union([s2, s1])])
        assert r_ab.canonical_hash() == r_ba.canonical_hash()
        assert r_ab.canonical_form() == r_ba.canonical_form()

    def test_permuted_select_values_collide(self):
        r1 = Request([Select("c", [3.0, 1.0, 2.0])])
        r2 = Request([Select("c", [1.0, 2.0, 3.0])])
        r3 = Request([Select("c", [1.0]), Select("c", [3.0, 2.0])])
        assert r1.canonical_hash() == r2.canonical_hash()
        assert r1.canonical_hash() == r3.canonical_hash()

    def test_duplicate_members_and_values_collide(self):
        s = Box(("a", "b"), [0, 0], [3, 3])
        assert (Request([Union([s, s]), Select("c", [1, 1])])
                .canonical_hash() ==
                Request([s, Select("c", [1])]).canonical_hash())

    def test_tolerance_quantized_vertices_collide(self):
        base = np.array([[0.0, 0.0], [8.0, 0.0], [0.0, 8.0]])
        jitter = base + 1e-13          # far below the quantum
        assert (Request([ConvexPolytope(("a", "b"), base)]).canonical_hash()
                == Request([ConvexPolytope(("a", "b"),
                                           jitter)]).canonical_hash())

    def test_geometrically_distinct_differ(self):
        assert (tri_request(0.0).canonical_hash()
                != tri_request(1.0).canonical_hash())
        assert (Request([Span("a", 0, 5)]).canonical_hash()
                != Request([Span("b", 0, 5)]).canonical_hash())
        assert (Request([Select("c", [1.0])]).canonical_hash()
                != Request([Select("c", [2.0])]).canonical_hash())

    def test_box_and_equivalent_polytope_collide(self):
        # is_box is an execution detail, not geometry — same plan bytes.
        box = Box(("a", "b"), [1, 2], [4, 5])
        verts = np.array([[1, 2], [1, 5], [4, 2], [4, 5]], float)
        assert (Request([box]).canonical_hash()
                == Request([ConvexPolytope(("a", "b"),
                                           verts)]).canonical_hash())

    def test_hash_is_stable_content_hash(self):
        # Process-independent: a fixed request pins its digest format.
        h = Request([Span("a", 0.0, 2.0)]).canonical_hash()
        assert isinstance(h, str) and len(h) == 64
        assert h == Request([Span("a", 0.0, 2.0)]).canonical_hash()


class TestPlanCacheLRU:
    def test_eviction_order_is_lru(self):
        pc = PlanCache(capacity=2)
        pc.put("k1", "p1")
        pc.put("k2", "p2")
        assert pc.get("k1") == "p1"        # k1 becomes MRU
        pc.put("k3", "p3")                 # evicts k2, not k1
        assert "k2" not in pc
        assert "k1" in pc and "k3" in pc
        assert pc.stats.evictions == 1

    def test_counters(self):
        pc = PlanCache(capacity=4)
        assert pc.get("missing") is None
        pc.put("k", "p")
        assert pc.get("k") == "p"
        assert pc.stats.hits == 1
        assert pc.stats.misses == 1
        assert pc.stats.hit_rate == 0.5


class TestExtractionService:
    def test_repeat_request_served_from_cache(self):
        svc = ExtractionService(small_cube())
        cold = svc.extract(tri_request())
        assert not cold.cached
        assert cold.stats is not None            # cold plan ran Alg. 1
        warm = svc.extract(tri_request())
        assert warm.cached
        assert warm.stats is None                # no new SliceStats
        assert svc.stats.hits == 1 and svc.stats.misses == 1
        # byte-identical offsets: the exact plan object is shared
        assert warm.plan is cold.plan
        np.testing.assert_array_equal(warm.plan.offsets, cold.plan.offsets)

    def test_hit_offsets_match_independent_cold_plan(self):
        cube = small_cube()
        svc = ExtractionService(cube)
        svc.extract(tri_request())
        hit = svc.extract(tri_request())
        ref, _ = Slicer(cube).extract_plan(tri_request())
        np.testing.assert_array_equal(hit.plan.offsets, ref.offsets)

    def test_batch_dedupes_and_shares_reads(self):
        cube = small_cube()
        data = np.arange(cube.n_elements, dtype=np.float64)
        svc = ExtractionService(cube)
        reqs = [tri_request(), tri_request(), tri_request(1.0)]
        results = svc.submit_batch(reqs, data)
        assert svc.stats.misses == 2             # two distinct geometries
        assert svc.stats.batch_dedup == 1        # in-batch duplicate
        assert results[1].plan is results[0].plan
        for res in results:
            np.testing.assert_array_equal(res.values,
                                          data[res.plan.offsets])
        # overlapping requests read shared bytes once
        assert svc.stats.bytes_read < svc.stats.bytes_requested
        assert svc.stats.sharing_factor > 1.0

    def test_equivalent_permuted_batch_members_hit(self):
        svc = ExtractionService(small_cube())
        s1 = Box(("a", "b"), [0, 0], [3, 3])
        s2 = Disk(("a", "b"), (6.0, 6.0), 2.0)
        svc.extract(Request([Union([s1, s2])]))
        res = svc.extract(Request([Union([s2, s1])]))
        assert res.cached

    def test_lru_eviction_end_to_end(self):
        svc = ExtractionService(small_cube(), capacity=2)
        r1, r2, r3 = tri_request(0.0), tri_request(1.0), tri_request(2.0)
        svc.extract(r1)
        svc.extract(r2)
        svc.extract(r1)                  # r1 MRU → order [r2, r1]
        svc.extract(r3)                  # evicts LRU r2 → [r1, r3]
        assert svc.stats.evictions == 1
        assert svc.extract(r1).cached
        assert svc.extract(r3).cached
        assert not svc.extract(r2).cached    # r2 was evicted

    def test_empty_plan_values(self):
        cube = small_cube()
        data = np.arange(cube.n_elements, dtype=np.float64)
        svc = ExtractionService(cube)
        # box entirely outside the grid → empty plan
        res = svc.extract(Request([Box(("a", "b"), [50, 50], [60, 60])]),
                          data)
        assert res.plan.n_points == 0
        assert len(res.values) == 0


class TestPrefetcherReusesPlans:
    def test_plans_cached_across_steps(self):
        cube = small_cube()
        data = np.arange(cube.n_elements, dtype=np.float64)
        svc = ExtractionService(cube)
        # recurring production mix: step alternates between two crops
        crops = [tri_request(0.0), tri_request(2.0)]
        src = CachedExtractionSource(svc, lambda s: crops[s % 2], data)
        pf = Prefetcher(src, depth=2)
        out = [next(pf) for _ in range(6)]
        pf.close()
        assert [s for s, _ in out] == list(range(6))
        assert svc.stats.misses == 2             # one cold plan per crop
        assert svc.stats.hits >= 4               # later steps all hit
        ref, _ = Slicer(cube).extract_plan(crops[0])
        np.testing.assert_array_equal(out[4][1].values, data[ref.offsets])


# ---------------------------------------------------------------------------
# Property-style canonical-hash invariants (ROADMAP: cache-key hardening).
# Base coordinates sit on the integer grid so CANON_TOL (1e-9) quantization
# is exact; jitter ≤ 2e-10 stays inside one quantum, 1e-6 jumps ~1000.
# ---------------------------------------------------------------------------

def _member(kind, p):
    """Small 2-D shape from 4 integer params (quantization-stable)."""
    p = [float(v) for v in p]
    if kind == 0:
        return Box(("a", "b"), [p[0], p[1]], [p[0] + p[2], p[1] + p[3]])
    if kind == 1:
        return Disk(("a", "b"), (p[0], p[1]), 1.0 + p[2])
    return ConvexPolytope(("a", "b"), np.array(
        [[p[0], p[1]], [p[0] + p[2], p[1]], [p[0], p[1] + p[3]]]))


_TRI = np.array([[0.0, 0.0], [7.0, 0.0], [0.0, 7.0]])


class TestCanonicalHashSeededProperties:
    """Seeded-rng versions of the hypothesis properties below — always run."""

    def test_union_member_permutation_collides(self):
        rng = np.random.default_rng(7)
        for _ in range(25):
            n = int(rng.integers(2, 5))
            members = [_member(int(rng.integers(0, 3)),
                               rng.integers(0, 6, size=4))
                       for _ in range(n)]
            perm = [members[i] for i in rng.permutation(n)]
            assert (Request([Union(members), Select("c", [1.0])])
                    .canonical_hash() ==
                    Request([Union(perm), Select("c", [1.0])])
                    .canonical_hash())

    def test_duplicate_select_labels_collide(self):
        rng = np.random.default_rng(8)
        for _ in range(25):
            vals = [float(v) for v in
                    rng.integers(0, 6, size=int(rng.integers(1, 6)))]
            dup = vals + [vals[int(rng.integers(0, len(vals)))]]
            rng.shuffle(dup)
            assert (Request([Select("c", sorted(set(vals)))]).canonical_hash()
                    == Request([Select("c", dup)]).canonical_hash())

    def test_sub_tolerance_jitter_collides(self):
        rng = np.random.default_rng(9)
        h0 = Request([ConvexPolytope(("a", "b"), _TRI)]).canonical_hash()
        for _ in range(25):
            jitter = rng.uniform(-2e-10, 2e-10, size=_TRI.shape)
            assert (Request([ConvexPolytope(("a", "b"), _TRI + jitter)])
                    .canonical_hash() == h0)

    def test_super_tolerance_perturbation_differs(self):
        rng = np.random.default_rng(10)
        h0 = Request([ConvexPolytope(("a", "b"), _TRI)]).canonical_hash()
        for _ in range(25):
            shift = np.zeros_like(_TRI)
            shift[rng.integers(0, 3), rng.integers(0, 2)] = (
                float(rng.choice([-1.0, 1.0])) * rng.uniform(1e-6, 1e-3))
            assert (Request([ConvexPolytope(("a", "b"), _TRI + shift)])
                    .canonical_hash() != h0)


if HAVE_HYPOTHESIS:
    _coord = st.integers(0, 6)
    _params = st.tuples(_coord, _coord,
                        st.integers(1, 5), st.integers(1, 5))
    _members = st.lists(st.tuples(st.integers(0, 2), _params),
                        min_size=2, max_size=4)
    _props = settings(deadline=None, max_examples=40)

    class TestCanonicalHashHypothesis:
        @_props
        @given(specs=_members, data=st.data())
        def test_union_member_permutation_collides(self, specs, data):
            members = [_member(k, p) for k, p in specs]
            order = data.draw(st.permutations(range(len(members))))
            perm = [members[i] for i in order]
            assert (Request([Union(members)]).canonical_hash()
                    == Request([Union(perm)]).canonical_hash())

        @_props
        @given(vals=st.lists(st.integers(0, 6), min_size=1, max_size=5),
               data=st.data())
        def test_duplicate_select_labels_collide(self, vals, data):
            vals = [float(v) for v in vals]
            dup = vals + [data.draw(st.sampled_from(vals))]
            dup = data.draw(st.permutations(dup))
            assert (Request([Select("c", sorted(set(vals)))]).canonical_hash()
                    == Request([Select("c", list(dup))]).canonical_hash())

        @_props
        @given(jitter=st.lists(
            st.floats(-2e-10, 2e-10, allow_nan=False, allow_infinity=False),
            min_size=6, max_size=6))
        def test_sub_tolerance_jitter_collides(self, jitter):
            j = np.array(jitter).reshape(3, 2)
            assert (Request([ConvexPolytope(("a", "b"), _TRI + j)])
                    .canonical_hash() ==
                    Request([ConvexPolytope(("a", "b"), _TRI)])
                    .canonical_hash())

        @_props
        @given(vi=st.integers(0, 2), ci=st.integers(0, 1),
               sign=st.sampled_from([-1.0, 1.0]),
               delta=st.floats(1e-6, 1e-3, allow_nan=False,
                               allow_infinity=False))
        def test_super_tolerance_perturbation_differs(self, vi, ci, sign,
                                                      delta):
            shift = np.zeros_like(_TRI)
            shift[vi, ci] = sign * delta
            assert (Request([ConvexPolytope(("a", "b"), _TRI + shift)])
                    .canonical_hash() !=
                    Request([ConvexPolytope(("a", "b"), _TRI)])
                    .canonical_hash())


class TestCacheStatsEdges:
    """Regressions for the sharing_factor division edge cases."""

    def test_sharing_factor_no_reads(self):
        from repro.serve.extraction import CacheStats
        assert CacheStats().sharing_factor == 1.0

    def test_sharing_factor_requested_but_nothing_read(self):
        # fully deduped batch: bytes were requested yet none hit storage
        from repro.serve.extraction import CacheStats
        st = CacheStats(bytes_requested=4096, bytes_read=0)
        assert st.sharing_factor == float("inf")

    def test_sharing_factor_ratio(self):
        from repro.serve.extraction import CacheStats
        st = CacheStats(bytes_requested=300, bytes_read=100)
        assert st.sharing_factor == 3.0


class TestPlanCachePeekAndPop:
    def test_peek_is_uncounted_and_preserves_lru_order(self):
        pc = PlanCache(capacity=2)
        pc.put("k1", "p1")
        pc.put("k2", "p2")
        assert pc.peek("k1") == "p1"
        assert pc.peek("missing") is None
        assert pc.stats.lookups == 0          # not a request-path lookup
        pc.put("k3", "p3")                    # k1 still LRU → evicted
        assert "k1" not in pc
        assert "k2" in pc and "k3" in pc

    def test_pop_counts_migrations_only_when_present(self):
        pc = PlanCache(capacity=4)
        pc.put("k", "p")
        assert pc.pop("k") == "p"
        assert pc.stats.migrations == 1
        assert pc.pop("k") is None            # second pop is a no-op
        assert pc.stats.migrations == 1


class TestQuantizeStraddle:
    """Two requests 0.75e-9 apart can quantize to *different* exact
    cache keys (the 1e-9 quantum boundary falls between them) while
    selecting identical cells.  The translation-invariant signature is
    immune — relative coordinates cancel the jitter — so the
    neighborhood index recovers the miss as a zero-shift delta hit that
    reuses the parent plan object outright."""

    JITTER = 0.75e-9

    def box_req(self, j=0.0):
        return Request([Box(("a", "b"), [3.0 + j, 3.0 + j],
                            [7.0 + j, 7.0 + j]),
                        Select("c", [1.0])])

    def test_straddled_keys_differ_but_signature_matches(self):
        r0, r1 = self.box_req(), self.box_req(self.JITTER)
        assert r0.canonical_hash() != r1.canonical_hash()
        assert r0.shape_signature()[0] == r1.shape_signature()[0]

    def test_neighborhood_recovers_straddled_miss(self):
        svc = ExtractionService(small_cube(), verify=True)
        r0, r1 = self.box_req(), self.box_req(self.JITTER)
        p0, cached0, _ = svc.plan(r0)
        p1, cached1, _ = svc.plan(r1)
        assert not cached0 and not cached1
        assert svc.stats.delta_hits == 1
        assert p1 is p0                       # zero-shift passthrough
        np.testing.assert_array_equal(p1.offsets, p0.offsets)

    def test_off_by_one_quantum_anchor_tolerance(self):
        # a whole-step drift plus sub-quantum jitter still resolves to
        # an integral step count (the ratio check absorbs the jitter)
        svc = ExtractionService(small_cube(), verify=True)
        svc.plan(self.box_req())
        plan, cached, _ = svc.plan(self.box_req(1.0 + self.JITTER))
        assert not cached
        assert svc.stats.delta_hits == 1
        cold = Slicer(small_cube()).extract_plan(
            self.box_req(1.0 + self.JITTER))[0]
        np.testing.assert_array_equal(plan.offsets, cold.offsets)
