import numpy as np
import pytest

from repro.core import (BoundingBoxExtractor, Box, BranchingDatacube,
                        CategoricalAxis, Disk, OctahedralGridDatacube,
                        OrderedAxis, Polygon, PolytopeExtractor, Request,
                        Select, Slicer, Span, TensorDatacube,
                        TraditionalExtractor, gather)


class TestTensorDatacube:
    def test_strides_and_offsets(self):
        axes = [OrderedAxis(n, np.arange(k, dtype=float))
                for n, k in [("a", 3), ("b", 4), ("c", 5)]]
        cube = TensorDatacube(axes)
        assert cube.n_elements == 60
        assert cube.base_offset({"a": 1, "b": 2, "c": 3}) == 20 + 10 + 3

    def test_extraction_matches_numpy(self):
        axes = [OrderedAxis(n, np.arange(6.0)) for n in "ab"]
        cube = TensorDatacube(axes)
        data = np.arange(36.0)
        res = PolytopeExtractor(cube).extract(
            Request([Box(("a", "b"), [1, 1], [3, 4])]), data)
        np.testing.assert_array_equal(
            np.sort(res.values),
            np.sort(data.reshape(6, 6)[1:4, 1:5].ravel()))


class TestOctahedralGrid:
    def test_o1280_field_size_matches_paper(self):
        # Table 1: one field is "50.4 MB" — O1280 @ float64.
        cube = OctahedralGridDatacube([], n=1280)
        assert cube.points_per_field == 6_599_680
        assert abs(cube.field_nbytes() / 2**20 - 50.35) < 0.1

    def test_row_structure(self):
        cube = OctahedralGridDatacube([], n=8)
        assert cube.row_counts[0] == 20
        assert cube.row_counts[7] == 20 + 4 * 7
        assert cube.row_counts[8] == 20 + 4 * 7   # mirror
        assert cube.points_per_field == cube.row_counts.sum()

    def test_offsets_unique_and_in_range(self):
        t = OrderedAxis("time", np.arange(3.0))
        cube = OctahedralGridDatacube([t], n=16)
        req = Request([Span("time", 0.0, 2.0),
                       Disk(("lat", "lon"), (30.0, 180.0), 20.0)])
        plan, _ = Slicer(cube).extract_plan(req)
        assert plan.n_points > 0
        assert len(set(plan.offsets.tolist())) == plan.n_points
        assert plan.offsets.min() >= 0
        assert plan.offsets.max() < cube.n_elements

    def test_imbalance_more_points_near_equator(self):
        cube = OctahedralGridDatacube([], n=64)
        eq = Request([Disk(("lat", "lon"), (0.0, 180.0), 10.0)])
        pole = Request([Disk(("lat", "lon"), (80.0, 180.0), 10.0)])
        peq, _ = Slicer(cube).extract_plan(eq)
        ppo, _ = Slicer(cube).extract_plan(pole)
        # the non-regular grid puts more longitudes near the equator
        assert peq.n_points > ppo.n_points

    def test_values_roundtrip(self):
        cube = OctahedralGridDatacube([], n=16)
        data = np.arange(cube.n_elements, dtype=np.float64)
        res = PolytopeExtractor(cube).extract(
            Request([Disk(("lat", "lon"), (0.0, 0.0), 15.0)]), data)
        np.testing.assert_array_equal(np.sort(res.values),
                                      np.sort(res.plan.offsets))


class TestBranchingDatacube:
    def _cube(self):
        cub_a = TensorDatacube(
            [OrderedAxis(n, np.arange(4.0)) for n in ("x", "y", "z")])
        cub_b = TensorDatacube(
            [OrderedAxis(n, np.arange(2.0)) for n in ("u", "v")])
        return BranchingDatacube("p", {"val4": cub_a, "val5": cub_b})

    def test_child_offsets_disjoint(self):
        cube = self._cube()
        assert cube.n_elements == 64 + 4
        r5 = Request([Select("p", ["val5"]), Box(("u", "v"), [0, 0], [1, 1])])
        plan, _ = Slicer(cube).extract_plan(r5)
        assert set(plan.offsets.tolist()) == {64, 65, 66, 67}

    def test_nonregular_axes_per_branch(self):
        cube = self._cube()
        both = Request([Select("p", ["val4", "val5"]),
                        Box(("x", "y", "z"), [0, 0, 0], [0, 0, 1]),
                        Box(("u", "v"), [0, 0], [0, 1])])
        plan, _ = Slicer(cube).extract_plan(both)
        assert set(plan.offsets.tolist()) == {0, 1, 64, 65}


class TestBaselines:
    def test_bbox_superset_of_polytope(self):
        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(20.0)) for n in ("x", "y")])
        req = Request([Disk(("x", "y"), (10.0, 10.0), 6.0)])
        ppoly, _ = PolytopeExtractor(cube).plan(req)
        pbox = BoundingBoxExtractor(cube).plan(req)
        assert set(ppoly.offsets.tolist()) <= set(pbox.offsets.tolist())
        assert pbox.nbytes >= ppoly.nbytes

    def test_reduction_factor_ordering(self):
        # paper Table 1: traditional >= bbox >= polytope, strictly for
        # non-orthogonal shapes.
        t = OrderedAxis("time", np.arange(8.0))
        cube = OctahedralGridDatacube([t], n=32)
        req = Request([Select("time", [3.0]),
                       Polygon(("lat", "lon"),
                               np.array([[40, 0], [55, 10], [50, 25],
                                         [35, 15]], float))])
        ppoly, _ = PolytopeExtractor(cube).plan(req)
        pbox = BoundingBoxExtractor(cube).plan(req)
        trad = TraditionalExtractor(cube).nbytes(req)
        assert trad >= pbox.nbytes >= ppoly.nbytes
        assert pbox.nbytes > ppoly.nbytes  # non-orthogonal shape

    def test_box_request_polytope_equals_bbox(self):
        # paper Table 1 rows 1-3: for orthogonal shapes the two match.
        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(30.0)) for n in ("x", "y")])
        req = Request([Box(("x", "y"), [3, 4], [10, 22])])
        ppoly, _ = PolytopeExtractor(cube).plan(req)
        pbox = BoundingBoxExtractor(cube).plan(req)
        assert ppoly.nbytes == pbox.nbytes


class TestGatherDevice:
    def test_jnp_gather(self):
        import jax.numpy as jnp

        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(10.0)) for n in ("x", "y")])
        data = jnp.arange(100.0)
        res = PolytopeExtractor(cube).extract(
            Request([Disk(("x", "y"), (5.0, 5.0), 3.0)]), data)
        np.testing.assert_array_equal(np.sort(np.asarray(res.values)),
                                      np.sort(res.plan.offsets))
