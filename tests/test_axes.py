import numpy as np
import pytest

from repro.core import CategoricalAxis, CyclicAxis, OrderedAxis


class TestOrderedAxis:
    def test_range_inclusive(self):
        ax = OrderedAxis("x", np.arange(10.0))
        pos, vals = ax.indices_in_range(2.0, 5.0)
        np.testing.assert_array_equal(pos, [2, 3, 4, 5])

    def test_irregular_sparse(self):
        ax = OrderedAxis("x", [0.0, 0.1, 5.0, 7.5, 100.0])
        pos, vals = ax.indices_in_range(0.05, 8.0)
        np.testing.assert_array_equal(vals, [0.1, 5.0, 7.5])

    def test_unsorted_storage_order(self):
        ax = OrderedAxis("lat", [90.0, 45.0, 0.0, -45.0, -90.0])
        pos, vals = ax.indices_in_range(-50.0, 50.0)
        # positions are storage positions
        assert set(pos.tolist()) == {1, 2, 3}
        np.testing.assert_array_equal(np.sort(vals), [-45.0, 0.0, 45.0])

    def test_datetime_axis(self):
        times = np.arange("2026-01-01", "2026-01-11", dtype="datetime64[D]")
        ax = OrderedAxis("time", times)
        lo = ax.to_float(np.datetime64("2026-01-03"))
        hi = ax.to_float(np.datetime64("2026-01-05"))
        pos, _ = ax.indices_in_range(lo, hi)
        assert len(pos) == 3

    def test_boundary_tolerance(self):
        ax = OrderedAxis("x", np.arange(100.0))
        pos, _ = ax.indices_in_range(10.0 - 1e-12, 20.0 + 1e-12)
        assert len(pos) == 11

    def test_nearest(self):
        ax = OrderedAxis("x", [0.0, 1.0, 10.0])
        assert ax.nearest(2.0) == (1, 1.0)
        assert ax.nearest(9.0) == (2, 10.0)


class TestCyclicAxis:
    def test_plain_range(self):
        ax = CyclicAxis("lon", np.arange(0.0, 360.0, 30.0), period=360.0)
        pos, vals = ax.indices_in_range(60.0, 150.0)
        np.testing.assert_array_equal(vals, [60., 90., 120., 150.])

    def test_wrap_negative(self):
        ax = CyclicAxis("lon", np.arange(0.0, 360.0, 30.0), period=360.0)
        pos, vals = ax.indices_in_range(-40.0, 40.0)
        assert set(pos.tolist()) == {11, 0, 1}          # 330, 0, 30
        np.testing.assert_array_equal(np.sort(vals), [-30., 0., 30.])

    def test_wrap_above(self):
        ax = CyclicAxis("lon", np.arange(0.0, 360.0, 30.0), period=360.0)
        pos, vals = ax.indices_in_range(330.0, 390.0)
        assert set(pos.tolist()) == {11, 0, 1}

    def test_full_circle(self):
        ax = CyclicAxis("lon", np.arange(0.0, 360.0, 30.0), period=360.0)
        pos, _ = ax.indices_in_range(-1000.0, 1000.0)
        assert len(pos) == 12
        assert len(set(pos.tolist())) == 12

    def test_no_duplicate_positions(self):
        ax = CyclicAxis("lon", np.arange(0.0, 360.0, 30.0), period=360.0)
        pos, _ = ax.indices_in_range(-360.0, 359.0)
        assert len(pos) == len(set(pos.tolist()))

    def test_nearest_wraps_across_seam(self):
        ax = CyclicAxis("lon", np.arange(0.0, 360.0, 30.0), period=360.0)
        # 350° is 10° from 0 (across the seam) but 20° from 330
        assert ax.nearest(350.0) == (0, 0.0)
        # out-of-period values fold before snapping
        assert ax.nearest(710.0) == (0, 0.0)
        assert ax.nearest(-14.0) == (0, 0.0)
        assert ax.nearest(-16.0) == (11, 330.0)
        # mid-axis values are untouched by the seam override
        assert ax.nearest(151.0) == (5, 150.0)

    def test_nearest_wrap_respects_storage_order(self):
        vals = np.arange(0.0, 360.0, 30.0)[::-1]    # stored descending
        ax = CyclicAxis("lon", vals, period=360.0)
        pos, val = ax.nearest(355.0)
        assert val == 0.0 and vals[pos] == 0.0


class TestCategoricalAxis:
    def test_find(self):
        ax = CategoricalAxis("param", ["t2m", "u10", "v10"])
        assert ax.find("u10") == 1
        assert ax.find("nope") is None

    def test_duplicate_labels_raise(self):
        with pytest.raises(ValueError):
            CategoricalAxis("p", ["a", "a"])

    def test_len_and_values(self):
        ax = CategoricalAxis("p", ["a", "b"])
        assert len(ax) == 2
        assert ax.values == ["a", "b"]
