"""Concurrency stress suite for the sharded extraction service
(DESIGN.md §7).

Barrier-started thread swarms hammer ``submit_batch`` and the
``AdmissionQueue`` with duplicate, seam-shifted, and disjoint requests;
every served value must be byte-identical to a fresh single-threaded
``PolytopeExtractor`` extraction, and the stats accounting must stay
consistent under contention (``lookups == hits + misses``,
coalesced ≤ submitted).  The shard-rebalance tests pin the consistent
hashing guarantee: adding a shard remaps only ~1/N of the key space,
and every remapped key moves *to the new shard*.

Swarm scale comes from env knobs so the CI fast lane runs a reduced
swarm while the scheduled lane runs the full one:

    REPRO_STRESS_THREADS   threads per swarm (default 8)
    REPRO_STRESS_ITERS     batches per thread (default 4)
"""

import hashlib
import os
import threading

import numpy as np
import pytest

from repro.core import PolytopeExtractor, gather
from repro.dataplane.weather import (IrregularWeatherCube, WeatherCube,
                                     request_population)
from repro.serve.extraction import ExtractionService, PlanCache
from repro.serve.sharded import (AdmissionQueue, ShardedExtractionService,
                                 ShardedPlanCache, deserialize_plan,
                                 serialize_plan)

N_THREADS = max(int(os.environ.get("REPRO_STRESS_THREADS", "8")), 2)
N_ITERS = max(int(os.environ.get("REPRO_STRESS_ITERS", "4")), 1)


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def run_swarm(n_threads, fn):
    """Start ``n_threads`` threads on a barrier (maximal contention at
    t=0) and re-raise the first exception any of them hit."""
    barrier = threading.Barrier(n_threads)
    errors = []

    def wrapped(tid):
        try:
            barrier.wait(timeout=30)
            fn(tid)
        except BaseException as e:   # noqa: BLE001 — surface everything
            errors.append(e)

    threads = [threading.Thread(target=wrapped, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "swarm deadlocked"
    if errors:
        raise errors[0]


def reference_values(cube, data, requests):
    """Fresh single-threaded extractions — the byte-identity oracle."""
    ex = PolytopeExtractor(cube)
    out = []
    for req in requests:
        plan, _ = ex.plan(req)
        out.append(gather(data, plan))
    return out


@pytest.fixture(scope="module")
def weather():
    wc = WeatherCube(n=16, n_times=2, n_levels=2)
    return wc, wc.field_data(seed=0), request_population(wc)


@pytest.fixture(scope="module")
def irregular():
    icw = IrregularWeatherCube(n_lat=24, n_lon=48)
    return icw, icw.field_data(seed=1)


# ---------------------------------------------------------------------------
# submit_batch under contention
# ---------------------------------------------------------------------------

class TestSubmitBatchSwarm:
    def _mix(self, population, tid):
        """Per-thread request mix: duplicates + disjoint geometries,
        rotated per thread so threads collide on *some* keys."""
        k = len(population)
        picks = [population[(tid + j) % k] for j in range(6)]
        return picks + picks[:2]   # in-batch duplicates

    def test_sharded_byte_identity_and_stats(self, weather):
        wc, data, population = weather
        svc = ShardedExtractionService(wc.cube, shards=4)
        refs = {id(r): v for r, v in
                zip(population, reference_values(wc.cube, data, population))}

        def worker(tid):
            for _ in range(N_ITERS):
                batch = self._mix(population, tid)
                results = svc.submit_batch(batch, data)
                assert len(results) == len(batch)
                for req, res in zip(batch, results):
                    assert res.request is req
                    assert np.array_equal(res.values, refs[id(req)])

        run_swarm(N_THREADS, worker)
        s = svc.stats
        assert s.lookups == s.hits + s.misses
        # per-shard planning locks: each distinct geometry planned once,
        # no matter how many threads raced on it
        covered = {(tid + j) % len(population)
                   for tid in range(N_THREADS) for j in range(6)}
        distinct = len({population[i].canonical_hash(svc.tol, svc.periods)
                        for i in covered})
        assert s.misses == distinct
        assert len(svc.shards) == distinct
        # 2 in-batch duplicates per batch, every batch
        assert s.batch_dedup == 2 * N_THREADS * N_ITERS

    def test_single_lock_service_parity(self, weather):
        """The original single-lock service stays race-free too."""
        wc, data, population = weather
        svc = ExtractionService(wc.cube)
        refs = reference_values(wc.cube, data, population)

        def worker(tid):
            for _ in range(N_ITERS):
                idx = [(tid + j) % len(population) for j in range(4)]
                results = svc.submit_batch([population[i] for i in idx],
                                           data)
                for i, res in zip(idx, results):
                    assert np.array_equal(res.values, refs[i])

        run_swarm(N_THREADS, worker)
        s = svc.stats
        assert s.lookups == s.hits + s.misses

    def test_seam_shifted_requests_share_one_plan(self, irregular):
        """Period-shifted seam crops hash identically, so a swarm half
        on lon −15…15 and half on lon 345…375 contends on ONE cache
        entry — and both halves read byte-identical values."""
        icw, data = irregular
        svc = ShardedExtractionService(icw.cube, shards=4)
        base = icw.seam_box_request(40.0, 60.0, -15.0, 15.0)
        shifted = icw.seam_box_request(40.0, 60.0, 345.0, 375.0)
        assert (base.canonical_hash(svc.tol, svc.periods)
                == shifted.canonical_hash(svc.tol, svc.periods))
        ref = reference_values(icw.cube, data, [base])[0]
        assert ref.size > 0

        def worker(tid):
            req = base if tid % 2 == 0 else shifted
            for _ in range(N_ITERS):
                res = svc.extract(req, data)
                assert np.array_equal(res.values, ref)

        run_swarm(N_THREADS, worker)
        s = svc.stats
        assert s.misses == 1           # one plan for both seam phrasings
        assert s.hits == N_THREADS * N_ITERS - 1
        assert s.lookups == s.hits + s.misses


# ---------------------------------------------------------------------------
# Async admission
# ---------------------------------------------------------------------------

class TestAdmissionQueue:
    def test_swarm_coalesces_across_callers(self, weather):
        wc, data, population = weather
        hot = population[:4]
        refs = reference_values(wc.cube, data, hot)
        svc = ShardedExtractionService(wc.cube, shards=4)

        with AdmissionQueue(svc, flat_data=data, window_s=0.005,
                            max_batch=256) as queue:
            def worker(tid):
                for j in range(N_ITERS):
                    i = (tid + j) % len(hot)
                    res = queue.extract(hot[i], timeout=60)
                    assert np.array_equal(res.values, refs[i])

            run_swarm(N_THREADS, worker)
            adm = queue.snapshot()

        total = N_THREADS * N_ITERS
        assert adm.submitted == total
        assert adm.served == total
        assert 0 <= adm.coalesced <= adm.submitted
        assert adm.windows >= 1
        assert adm.coalescing_factor >= 1.0
        # N_THREADS barrier-released threads over 4 hot keys: the first
        # window alone must fold duplicates across callers
        if N_THREADS > len(hot):
            assert adm.coalesced > 0

    def test_futures_resolve_out_of_band(self, weather):
        wc, data, population = weather
        svc = ShardedExtractionService(wc.cube, shards=2)
        queue = AdmissionQueue(svc, flat_data=data, window_s=0.001)
        futs = [queue.submit(population[i % 5]) for i in range(16)]
        refs = reference_values(wc.cube, data, population[:5])
        # futures resolve to ServiceResults carrying the right bytes
        for i, fut in enumerate(futs):
            assert np.array_equal(fut.result(timeout=60).values,
                                  refs[i % 5])
        queue.close()

    def test_submit_after_close_raises(self, weather):
        wc, data, population = weather
        queue = AdmissionQueue(ShardedExtractionService(wc.cube, shards=2),
                               flat_data=data)
        queue.close()
        with pytest.raises(RuntimeError):
            queue.submit(population[0])

    def test_service_error_propagates_to_futures(self, weather):
        _, _, population = weather

        class Exploding:
            def submit_batch(self, requests, flat_data=None):
                raise ValueError("boom")

        with AdmissionQueue(Exploding(), window_s=0.001) as queue:
            fut = queue.submit(population[0])
            with pytest.raises(ValueError, match="boom"):
                fut.result(timeout=30)


# ---------------------------------------------------------------------------
# verify=True under the admission path (plan_check on every union plan)
# ---------------------------------------------------------------------------

class TestVerifiedAdmission:
    def test_irregular_stress_roundtrip_verified(self, irregular):
        """Every cold plan AND every coalesced window's union plan runs
        ``plan_check.verify_plan`` (verify=True raises on violation) —
        the async analogue of PR 4's synchronous verify coverage."""
        icw, data = irregular
        svc = ShardedExtractionService(icw.cube, shards=4, verify=True)
        requests = [
            icw.country_request("uk"),
            icw.country_request("france"),
            icw.seam_box_request(40.0, 60.0, -15.0, 15.0),
            icw.seam_box_request(40.0, 60.0, 345.0, 375.0),
            icw.timeseries_request(float(icw.latitudes[5]),
                                   float(icw.lon_values[4]),
                                   0.0, 100000.0),
        ]
        refs = reference_values(icw.cube, data, requests)

        with AdmissionQueue(svc, flat_data=data, window_s=0.005,
                            max_batch=128) as queue:
            def worker(tid):
                for j in range(N_ITERS):
                    i = (tid + j) % len(requests)
                    res = queue.extract(requests[i], timeout=60)
                    assert np.array_equal(res.values, refs[i])

            run_swarm(N_THREADS, worker)
            adm = queue.snapshot()
        assert adm.served == N_THREADS * N_ITERS
        assert svc.stats.lookups == svc.stats.hits + svc.stats.misses


# ---------------------------------------------------------------------------
# Shard rebalance: the consistent-hashing contract
# ---------------------------------------------------------------------------

def _synthetic_keys(n, seed):
    rng = np.random.default_rng(seed)
    return [hashlib.sha256(rng.bytes(16)).hexdigest() for _ in range(n)]


class TestShardRebalance:
    N_KEYS = 2000
    SEED = 1234

    def test_add_shard_remaps_about_one_over_n(self):
        cache = ShardedPlanCache(shards=4, capacity_per_shard=self.N_KEYS)
        keys = _synthetic_keys(self.N_KEYS, self.SEED)
        for i, k in enumerate(keys):
            cache.put(k, f"plan-{i}")
        before = {k: cache.entry_of(k)[0] for k in keys}

        moved = cache.add_shard("shard4")
        after = {k: cache.entry_of(k)[0] for k in keys}

        remapped = [k for k in keys if before[k] != after[k]]
        frac = len(remapped) / self.N_KEYS
        # ideal 1/5 = 0.20; 64 virtual points keeps it in a tight band
        assert 0.10 <= frac <= 0.35, f"remap fraction {frac:.3f}"
        # consistent hashing: keys only ever move TO the new shard
        assert all(after[k] == "shard4" for k in remapped)
        assert moved == len(remapped)
        # no entry lost in migration
        for i, k in enumerate(keys):
            assert cache.get(k) == f"plan-{i}"
        assert len(cache) == self.N_KEYS

    def test_migrations_counter_matches_moved(self):
        """Regression: ``PlanCache.pop`` must count each entry actually
        drained during rebalance, so fleet-wide ``stats.migrations``
        equals the migration report — and nothing else inflates it."""
        cache = ShardedPlanCache(shards=4, capacity_per_shard=self.N_KEYS)
        keys = _synthetic_keys(500, self.SEED + 2)
        for i, k in enumerate(keys):
            cache.put(k, i)
        assert cache.stats.migrations == 0
        moved = cache.add_shard("shard4")
        assert cache.stats.migrations == moved
        moved_back = cache.remove_shard("shard4")
        # remove_shard folds the drained shard's counters into a
        # survivor, so the add-phase migrations are preserved too
        assert cache.stats.migrations == moved + moved_back
        for i, k in enumerate(keys):
            assert cache.get(k) == i

    def test_remove_shard_conserves_stats(self):
        cache = ShardedPlanCache(shards=3, capacity_per_shard=self.N_KEYS)
        keys = _synthetic_keys(300, self.SEED + 3)
        for i, k in enumerate(keys):
            cache.put(k, i)
        for k in keys:
            cache.get(k)
        hits_before = cache.stats.hits
        cache.add_shard("doomed")
        cache.remove_shard("doomed")
        assert cache.stats.hits == hits_before

    def test_add_then_remove_restores_routing(self):
        cache = ShardedPlanCache(shards=4, capacity_per_shard=self.N_KEYS)
        keys = _synthetic_keys(500, self.SEED + 1)
        for i, k in enumerate(keys):
            cache.put(k, i)
        before = {k: cache.entry_of(k)[0] for k in keys}
        cache.add_shard("extra")
        cache.remove_shard("extra")
        assert {k: cache.entry_of(k)[0] for k in keys} == before
        for i, k in enumerate(keys):
            assert cache.get(k) == i

    def test_rebalance_under_concurrent_service_load(self, weather):
        """Adding a shard mid-swarm never serves wrong bytes."""
        wc, data, population = weather
        svc = ShardedExtractionService(wc.cube, shards=3)
        refs = reference_values(wc.cube, data, population)
        stop = threading.Event()

        def admin(tid):
            if tid == 0:
                svc.add_shard("late-shard")
                stop.set()
                return
            j = 0
            while not stop.is_set() or j < len(population):
                i = (tid + j) % len(population)
                res = svc.extract(population[i], data)
                assert np.array_equal(res.values, refs[i])
                j += 1
                if j > 10 * len(population):
                    break

        run_swarm(max(N_THREADS, 3), admin)
        assert "late-shard" in svc.shards.shard_names


# ---------------------------------------------------------------------------
# PlanCache reader/writer races (regression for the unsynchronized
# keys()/__contains__ reads — the static fixture lives in
# tests/test_analysis.py, this is the live hammer)
# ---------------------------------------------------------------------------

class TestPlanCacheConcurrentReads:
    def test_keys_and_contains_race_concurrent_eviction(self):
        cache = PlanCache(capacity=8)

        def worker(tid):
            if tid % 2 == 0:
                for i in range(500):
                    cache.put(f"k{tid}-{i}", i)
            else:
                for _ in range(500):
                    ks = cache.keys()       # iterates the OrderedDict
                    assert len(ks) <= 8
                    for k in ks[:2]:
                        k in cache          # noqa: B015 — probe only
                    len(cache)

        # pre-lock, this raised "OrderedDict mutated during iteration"
        run_swarm(N_THREADS, worker)
        assert len(cache) <= 8
        s = cache.snapshot()
        assert s.evictions > 0


# ---------------------------------------------------------------------------
# Cross-replica plan shipping
# ---------------------------------------------------------------------------

class TestPlanShipping:
    def test_wire_roundtrip(self, weather):
        wc, _, population = weather
        svc = ShardedExtractionService(wc.cube, shards=2)
        plan, _, key = svc.plan(population[0])
        key2, plan2 = deserialize_plan(
            serialize_plan(key, plan, n_elements=wc.cube.n_elements))
        assert key2 == key
        assert np.array_equal(plan2.offsets, plan.offsets)

    def test_corrupt_shipment_rejected(self, weather):
        from repro.analysis.plan_check import PlanVerificationError

        wc, _, population = weather
        svc = ShardedExtractionService(wc.cube, shards=2)
        plan, _, key = svc.plan(population[0])
        bad = type(plan)(
            offsets=plan.offsets + wc.cube.n_elements,   # out of bounds
            run_starts=plan.run_starts, run_lengths=plan.run_lengths,
            coords={}, itemsize=plan.itemsize)
        blob = serialize_plan(key, bad, n_elements=wc.cube.n_elements)
        with pytest.raises(PlanVerificationError):
            deserialize_plan(blob, verify=True)

    def test_swarm_on_one_replica_warms_the_peer(self, weather):
        wc, data, population = weather
        primary = ShardedExtractionService(wc.cube, shards=4,
                                           name="replica0")
        peer = ShardedExtractionService(wc.cube, shards=4,
                                        name="replica1")
        primary.connect_peer(peer)

        def worker(tid):
            for j in range(N_ITERS):
                primary.extract(population[(tid + j) % len(population)])

        run_swarm(N_THREADS, worker)
        covered = sorted({(tid + j) % len(population)
                          for tid in range(N_THREADS)
                          for j in range(N_ITERS)})
        expected_keys = {population[i].canonical_hash(primary.tol,
                                                      primary.periods)
                         for i in covered}
        assert peer.stats.plans_received == len(expected_keys)
        assert primary.stats.plans_shipped == peer.stats.plans_received
        # the peer never plans: every request the primary saw is warm
        refs = reference_values(wc.cube, data, population)
        for i in covered:
            res = peer.extract(population[i], data)
            assert res.cached
            assert np.array_equal(res.values, refs[i])
        assert peer.stats.misses == 0
