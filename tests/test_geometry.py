import numpy as np
import pytest

from repro.core import (Polytope, box_polytope, convex_hull_prune,
                        regular_polygon, slice_vertices)


class TestSliceVertices:
    def test_square_slice_middle(self):
        pts = np.array([[0., 0.], [4., 0.], [0., 4.], [4., 4.]])
        out = slice_vertices(pts, 0, 2.0)
        assert out is not None
        ys = np.sort(out[:, 0])
        np.testing.assert_allclose(ys[[0, -1]], [0.0, 4.0])

    def test_miss_returns_none(self):
        pts = np.array([[0., 0.], [1., 0.], [0., 1.]])
        assert slice_vertices(pts, 0, 5.0) is None
        assert slice_vertices(pts, 0, -5.0) is None

    def test_touch_vertex(self):
        pts = np.array([[0., 0.], [1., 0.], [0., 1.]])
        out = slice_vertices(pts, 0, 1.0)
        assert out is not None
        np.testing.assert_allclose(out, [[0.0]])

    def test_tetrahedron_mid_slice_is_triangle(self):
        pts = np.array([[0., 0., 0.], [2., 0., 0.], [0., 2., 0.],
                        [0., 0., 2.]])
        out = slice_vertices(pts, 2, 1.0)
        out = convex_hull_prune(out)
        assert len(out) == 3  # triangle cross-section

    def test_interpolation_exact(self):
        pts = np.array([[0., 10.], [4., 30.]])
        out = slice_vertices(pts, 0, 1.0)
        np.testing.assert_allclose(out, [[15.0]])


class TestPolytope:
    def test_dedupe_on_init(self):
        p = Polytope(("x", "y"), np.array([[0., 0.], [0., 0.], [1., 1.]]))
        assert p.n_vertices == 2

    def test_extents(self):
        p = box_polytope(["x", "y"], [1., 2.], [3., 5.])
        assert p.extents("x") == (1., 3.)
        assert p.extents("y") == (2., 5.)

    def test_slice_drops_axis(self):
        p = box_polytope(["x", "y", "z"], [0., 0., 0.], [1., 1., 1.])
        s = p.slice_at("y", 0.5)
        assert s.axes == ("x", "z")
        assert s.ndim == 2

    def test_slice_to_zero_dim(self):
        p = Polytope(("x",), np.array([[0.], [2.]]))
        s = p.slice_at("x", 1.0)
        assert s.axes == ()

    def test_contains_lp_oracle(self):
        p = box_polytope(["x", "y"], [0., 0.], [2., 2.])
        assert p.contains([1., 1.])
        assert p.contains([0., 0.])
        assert not p.contains([3., 1.])

    def test_bad_shape_raises(self):
        with pytest.raises(ValueError):
            Polytope(("x",), np.zeros((3, 2)))


class TestHullPrune:
    def test_interior_point_removed(self):
        pts = np.array([[0., 0.], [4., 0.], [0., 4.], [4., 4.], [2., 2.]])
        out = convex_hull_prune(pts)
        assert len(out) == 4
        assert not any((out == [2., 2.]).all(1))

    def test_collinear_degenerate(self):
        pts = np.array([[0., 0.], [1., 1.], [2., 2.], [3., 3.]])
        out = convex_hull_prune(pts)
        assert len(out) == 2

    def test_1d(self):
        out = convex_hull_prune(np.array([[3.], [1.], [7.], [5.]]))
        np.testing.assert_allclose(sorted(out[:, 0]), [1., 7.])

    def test_quadratic_growth_suppressed(self):
        # paper §3.2: without pruning, vertex count grows quadratically.
        p = box_polytope(list("abcde"), [0.] * 5, [3.] * 5)
        cur = p
        for ax in "abcd":
            cur = cur.slice_at(ax, 1.5)
        assert cur.n_vertices <= 4  # 1-D remnant: 2 after pruning


class TestShapeFactories:
    def test_box_corners(self):
        p = box_polytope(["a", "b", "c"], [0.] * 3, [1.] * 3)
        assert p.n_vertices == 8

    def test_regular_polygon(self):
        p = regular_polygon(["x", "y"], (0., 0.), 2.0, n=8)
        assert p.n_vertices == 8
        r = np.linalg.norm(p.points, axis=1)
        np.testing.assert_allclose(r, 2.0)


class TestHullRegressions:
    def test_subnormal_coordinates_keep_hull_vertices(self):
        """hypothesis-found: an absolute epsilon in the 2-D monotone
        chain dropped true hull vertices when coordinates were
        subnormal (≈1e-75), losing interior datacube points."""
        import numpy as np

        from repro.core import (ConvexPolytope, OrderedAxis, Request,
                                Slicer, TensorDatacube)

        verts = np.array([
            [7.3, 1.0, -1.83000034e-74, 0.0],
            [0.0, 7.3, 0.0, 0.0],
            [0.0, 0.0, 7.3, 0.0],
            [0.0, 0.0, 0.0, 7.3],
            [0.0, 0.0, 0.0, 0.0],
            [2.0, 0.0, 0.0, 0.0]])
        names = ("ax0", "ax1", "ax2", "ax3")
        cube = TensorDatacube(
            [OrderedAxis(n, np.arange(10.0)) for n in names])
        plan, _ = Slicer(cube).extract_plan(
            Request([ConvexPolytope(names, verts)]))
        got = set(map(tuple,
                      np.stack([plan.coords[a] for a in names], -1)))
        assert (1.0, 2.0, 1.0, 1.0) in got
