"""Delta planner (DESIGN.md §8): differential suite against cold planning.

The contract under test: for every eligible drift, ``DeltaPlanner.splice``
must emit a plan *byte-identical* to running Algorithm 1 cold on the
drifted request — same offsets, same coalesced runs, same coords, same
§5.2 slice statistics — and every ineligible drift must fall back
(``None``) transparently, never emit an approximate plan.

Drift deltas in these tests are exact float64 multiples of the axis
steps (lon step 10 deg on the 36-column test cube, datetime step 28800 s
with 3 times/day, integer levels), so cold and spliced cell selection
cannot diverge through rounding.
"""

import numpy as np
import pytest

from repro.analysis.plan_check import verify_plan
from repro.core import (Box, DeltaPlanner, Polygon, PolytopeExtractor,
                        Request, Select, Span)
from repro.dataplane.weather import COUNTRIES, IrregularWeatherCube
from repro.serve.extraction import ExtractionService, NeighborhoodIndex
from repro.serve.sharded import ShardedExtractionService

LON_STEP = 10.0          # 360 / 36
DT_STEP = 28800.0        # 86400 / 3 times per day


@pytest.fixture(scope="module")
def wcube():
    return IrregularWeatherCube(n_dates=2, times_per_day=3, n_levels=4,
                                n_lat=24, n_lon=36)


@pytest.fixture(scope="module")
def extractor(wcube):
    return PolytopeExtractor(wcube.cube)


@pytest.fixture(scope="module")
def planner(wcube, extractor):
    return DeltaPlanner(wcube.cube, slicer=extractor.slicer)


def lon_box(lon_lo, lon_hi, lat_lo=20.0, lat_hi=70.0, datetime=0.0,
            level=1.0):
    return Request([Select("datetime", [datetime]),
                    Select("level", [level]),
                    Box(("lat", "lon"), [lat_lo, lon_lo],
                        [lat_hi, lon_hi])])


def window_req(t0, n_steps=3, level=1.0):
    return Request([Span("datetime", t0, t0 + (n_steps - 1) * DT_STEP),
                    Select("level", [level]),
                    Box(("lat", "lon"), [10.0, 40.0], [60.0, 120.0])])


def assert_identical(plan, stats, cold_plan, cold_stats):
    np.testing.assert_array_equal(plan.offsets, cold_plan.offsets)
    np.testing.assert_array_equal(plan.run_starts, cold_plan.run_starts)
    np.testing.assert_array_equal(plan.run_lengths, cold_plan.run_lengths)
    assert set(plan.coords) == set(cold_plan.coords)
    for k in plan.coords:
        np.testing.assert_array_equal(plan.coords[k], cold_plan.coords[k])
    assert plan.itemsize == cold_plan.itemsize
    assert stats.n_points == cold_stats.n_points
    assert stats.n_slices == cold_stats.n_slices
    assert stats.n_slices_by_dim == cold_stats.n_slices_by_dim


def splice_or_fail(planner, extractor, r_old, r_new, dc):
    """Plan r_old cold, splice to r_new, and differentially check the
    result against planning r_new cold.  Fails the test on fallback."""
    sig_old, a_old = r_old.shape_signature()
    sig_new, a_new = r_new.shape_signature()
    assert sig_old == sig_new, "drift must preserve the shape signature"
    shifts = planner.axis_shifts(a_old, a_new)
    assert shifts is not None
    p_old, s_old = extractor.plan(r_old)
    out = planner.splice(r_new, r_old, p_old, s_old, shifts)
    assert out is not None, f"unexpected fallback for shifts={shifts}"
    plan, stats = out
    verify_plan(plan, datacube=dc, stats=stats)
    cold_plan, cold_stats = extractor.plan(r_new)
    assert_identical(plan, stats, cold_plan, cold_stats)
    return shifts


class TestEligibility:
    def test_probed_axes(self, planner):
        assert set(planner._info) == {"datetime", "level", "lon"}
        assert planner._info["lon"].cyclic
        assert not planner._info["datetime"].cyclic
        assert planner._info["level"].step == 1.0

    def test_gaussian_lat_is_ineligible(self, planner):
        # non-uniform mapped axis: any lat drift must force a cold plan
        assert planner.axis_shifts({"lat": 20.0}, {"lat": 21.0}) is None

    def test_zero_delta_axes_are_dropped(self, planner):
        shifts = planner.axis_shifts({"lon": 40.0, "level": 1.0},
                                     {"lon": 50.0, "level": 1.0})
        assert shifts == {"lon": (10.0, 1)}

    def test_fractional_step_is_rejected(self, planner):
        assert planner.axis_shifts({"lon": 40.0}, {"lon": 44.0}) is None

    def test_drift_radius_bound(self, wcube, extractor):
        dp = DeltaPlanner(wcube.cube, slicer=extractor.slicer, max_steps=2)
        assert dp.axis_shifts({"lon": 0.0}, {"lon": 20.0}) is not None
        assert dp.axis_shifts({"lon": 0.0}, {"lon": 30.0}) is None

    def test_anchor_key_mismatch(self, planner):
        assert planner.axis_shifts({"lon": 0.0},
                                   {"lon": 0.0, "level": 1.0}) is None


class TestSpliceByteIdentity:
    def test_lon_box_single_step(self, planner, extractor, wcube):
        shifts = splice_or_fail(planner, extractor,
                                lon_box(34.0, 76.0),
                                lon_box(44.0, 86.0), wcube.cube)
        assert shifts == {"lon": (LON_STEP, 1)}

    def test_lon_box_multi_step_and_negative(self, planner, extractor,
                                             wcube):
        for k in (3, -2, 7):
            splice_or_fail(planner, extractor, lon_box(34.0, 76.0),
                           lon_box(34.0 + k * LON_STEP,
                                   76.0 + k * LON_STEP), wcube.cube)

    def test_lon_box_crosses_seam(self, planner, extractor, wcube):
        # box drifts over the 360/0 wrap; offsets wrap within the digit
        splice_or_fail(planner, extractor, lon_box(311.0, 353.0),
                       lon_box(331.0, 373.0), wcube.cube)

    def test_wrapping_drift_reduces_mod_circle(self, planner, extractor,
                                               wcube):
        # +33 columns on a 36-column circle is really −3: the reduced
        # shift stays inside the drift radius and splices exactly
        shifts = splice_or_fail(planner, extractor, lon_box(34.0, 76.0),
                                lon_box(34.0 + 33 * LON_STEP,
                                        76.0 + 33 * LON_STEP), wcube.cube)
        assert shifts["lon"][1] == -3

    def test_level_interior_drift(self, planner, extractor, wcube):
        shifts = splice_or_fail(planner, extractor,
                                lon_box(34.0, 76.0, level=1.0),
                                lon_box(34.0, 76.0, level=2.0), wcube.cube)
        assert shifts == {"level": (1.0, 1)}

    def test_combined_lon_and_level_drift(self, planner, extractor, wcube):
        splice_or_fail(planner, extractor,
                       lon_box(34.0, 76.0, level=1.0),
                       lon_box(54.0, 96.0, level=2.0), wcube.cube)

    def test_rolling_window_forward(self, planner, extractor, wcube):
        # lead-axis Span drift: 2 slabs kept, 1 fresh, 1 dropped
        splice_or_fail(planner, extractor, window_req(0.0),
                       window_req(DT_STEP), wcube.cube)

    def test_rolling_window_backward(self, planner, extractor, wcube):
        splice_or_fail(planner, extractor, window_req(2 * DT_STEP),
                       window_req(DT_STEP), wcube.cube)

    def test_rolling_window_two_steps(self, planner, extractor, wcube):
        # only 1 of 3 slabs overlaps the parent window
        splice_or_fail(planner, extractor, window_req(0.0),
                       window_req(2 * DT_STEP), wcube.cube)

    def test_disjoint_windows_still_splice(self, planner, extractor, wcube):
        # zero window overlap is still a pure translation on a uniform
        # lead axis: every slab's sub-tree is identical, so the whole
        # plan shifts arithmetically without re-slicing anything
        splice_or_fail(planner, extractor, window_req(0.0),
                       window_req(3 * DT_STEP), wcube.cube)

    def test_lead_select_drift(self, planner, extractor, wcube):
        splice_or_fail(planner, extractor,
                       lon_box(34.0, 76.0, datetime=0.0),
                       lon_box(34.0, 76.0, datetime=2 * DT_STEP),
                       wcube.cube)

    def test_storm_track_polygon(self, planner, extractor, wcube):
        def storm(d):
            verts = COUNTRIES["france"].copy()
            verts[:, 1] += d
            return Request([Select("datetime", [0.0]),
                            Select("level", [1.0]),
                            Polygon(("lat", "lon"), verts)])
        splice_or_fail(planner, extractor, storm(0.0), storm(2 * LON_STEP),
                       wcube.cube)

    def test_seeded_drift_sweep(self, planner, extractor, wcube):
        rng = np.random.default_rng(7)
        prev = lon_box(34.0, 76.0)
        lon = 34.0
        for _ in range(12):
            k = int(rng.integers(-4, 5))
            if k == 0:
                continue
            lon += k * LON_STEP
            cur = lon_box(lon, lon + 42.0)
            splice_or_fail(planner, extractor, prev, cur, wcube.cube)
            prev = cur

    def test_zero_shift_passthrough_reuses_parent(self, planner, extractor):
        r = lon_box(34.0, 76.0)
        p, s = extractor.plan(r)
        out = planner.splice(r, r, p, s, {})
        assert out is not None
        plan, stats = out
        assert plan is p            # parent object reused, not copied
        assert stats.n_points == s.n_points
        assert stats.n_slices_by_dim == s.n_slices_by_dim


class TestFallbackTransparency:
    def test_boundary_level_select_falls_back(self, planner, extractor):
        # shifted non-lead, non-cyclic axes need both windows interior;
        # level 0 sits on the axis edge, so the drift must plan cold
        r_old = lon_box(34.0, 76.0, level=0.0)
        r_new = lon_box(34.0, 76.0, level=1.0)
        shifts = planner.axis_shifts(r_old.shape_signature()[1],
                                     r_new.shape_signature()[1])
        assert shifts is not None
        p, s = extractor.plan(r_old)
        assert planner.splice(r_new, r_old, p, s, shifts) is None

    def test_near_full_circle_cyclic_falls_back(self, planner, extractor):
        # a lon window wider than period − step can alias across the
        # seam under shifting — the splicer refuses it
        r_old = lon_box(1.0, 352.0)
        r_new = lon_box(11.0, 362.0)
        shifts = planner.axis_shifts(r_old.shape_signature()[1],
                                     r_new.shape_signature()[1])
        assert shifts is not None
        p, s = extractor.plan(r_old)
        assert planner.splice(r_new, r_old, p, s, shifts) is None

    def test_service_falls_back_cold_on_lat_drift(self, wcube):
        svc = ExtractionService(wcube.cube, verify=True)
        cold = PolytopeExtractor(wcube.cube)
        r0 = lon_box(34.0, 76.0, lat_lo=20.0, lat_hi=60.0)
        r1 = lon_box(34.0, 76.0, lat_lo=25.0, lat_hi=65.0)
        svc.plan(r0)
        plan, cached, _ = svc.plan(r1)
        assert not cached
        assert svc.stats.delta_hits == 0
        np.testing.assert_array_equal(plan.offsets, cold.plan(r1)[0].offsets)


class TestServiceDelta:
    def test_drift_stream_counters_and_values(self, wcube):
        svc = ExtractionService(wcube.cube, verify=True)
        data = wcube.field_data(seed=3)
        results = []
        for k in range(6):
            r = lon_box(34.0 + k * LON_STEP, 76.0 + k * LON_STEP)
            results.append(svc.extract(r, data))
        st = svc.stats
        assert st.delta_hits == 5
        assert st.misses == 6 and st.hits == 0
        assert st.lookups == st.hits + st.misses
        for res in results:
            np.testing.assert_array_equal(res.values,
                                          data[res.plan.offsets])
        # the exact key was installed: replay is a plain cache hit
        res = svc.extract(lon_box(34.0 + 5 * LON_STEP,
                                  76.0 + 5 * LON_STEP), data)
        assert res.cached

    def test_spliced_equals_cold_service(self, wcube):
        warm = ExtractionService(wcube.cube, verify=True, delta=True)
        cold = ExtractionService(wcube.cube, verify=True, delta=False)
        for k in range(4):
            r = lon_box(34.0 + k * LON_STEP, 76.0 + k * LON_STEP)
            pw, _, _ = warm.plan(r)
            pc, _, _ = cold.plan(r)
            np.testing.assert_array_equal(pw.offsets, pc.offsets)
            np.testing.assert_array_equal(pw.run_starts, pc.run_starts)
        assert warm.stats.delta_hits == 3
        assert cold.stats.delta_hits == 0

    def test_evicted_parent_plans_cold(self, wcube):
        svc = ExtractionService(wcube.cube, capacity=1, verify=True)
        r0, r1 = lon_box(34.0, 76.0), lon_box(44.0, 86.0)
        svc.plan(r0)
        # parent evicted by an unrelated plan: neighborhood entry is
        # stale, peek misses, and the drifted request must plan cold
        svc.plan(window_req(0.0))
        plan, cached, _ = svc.plan(r1)
        assert not cached and plan.n_points > 0

    def test_delta_disabled_has_no_neighborhood(self, wcube):
        svc = ExtractionService(wcube.cube, delta=False)
        svc.plan(lon_box(34.0, 76.0))
        svc.plan(lon_box(44.0, 86.0))
        assert svc.stats.delta_hits == 0 and svc.stats.delta_misses == 0


class TestNeighborhoodIndex:
    def test_per_signature_bound_and_mru_order(self):
        idx = NeighborhoodIndex(capacity=16, per_signature=2)
        for i in range(3):
            idx.add("sig", f"k{i}", {"lon": float(i)}, None, None)
        cands = idx.candidates("sig")
        assert [c.key for c in cands] == ["k2", "k1"]   # MRU first, k0 out

    def test_capacity_evicts_lru_signature(self):
        idx = NeighborhoodIndex(capacity=2, per_signature=4)
        idx.add("s1", "a", {}, None, None)
        idx.add("s2", "b", {}, None, None)
        idx.add("s3", "c", {}, None, None)
        assert idx.candidates("s1") == []
        assert len(idx.candidates("s3")) == 1

    def test_pop_and_install_roundtrip(self):
        idx = NeighborhoodIndex(capacity=8)
        idx.add("s1", "a", {"lon": 1.0}, None, None)
        moved = idx.pop_signature("s1")
        assert idx.candidates("s1") == []
        idx2 = NeighborhoodIndex(capacity=8)
        idx2.install("s1", moved)
        assert [c.key for c in idx2.candidates("s1")] == ["a"]


class TestShardedDelta:
    def test_drift_stream_parity_and_counters(self, wcube):
        svc = ShardedExtractionService(wcube.cube, shards=3,
                                       capacity_per_shard=64, verify=True)
        cold = PolytopeExtractor(wcube.cube)
        data = wcube.field_data(seed=5)
        for k in range(5):
            r = lon_box(34.0 + k * LON_STEP, 76.0 + k * LON_STEP)
            res = svc.extract(r, data)
            np.testing.assert_array_equal(res.plan.offsets,
                                          cold.plan(r)[0].offsets)
            np.testing.assert_array_equal(res.values,
                                          data[res.plan.offsets])
        assert svc.shards.stats.delta_hits == 4

    def test_signature_routing_is_consistent(self, wcube):
        # every member of a drift chain shares one signature, so the
        # chain lands in exactly one shard's neighborhood index
        svc = ShardedExtractionService(wcube.cube, shards=4,
                                       capacity_per_shard=64)
        for k in range(4):
            svc.plan(lon_box(34.0 + k * LON_STEP, 76.0 + k * LON_STEP))
        populated = [n for n, h in svc.shards._hoods.items() if len(h)]
        assert len(populated) == 1

    def test_rebalance_migrates_neighborhoods(self, wcube):
        svc = ShardedExtractionService(wcube.cube, shards=2,
                                       capacity_per_shard=64, verify=True)
        for k in range(3):
            svc.plan(lon_box(34.0 + k * LON_STEP, 76.0 + k * LON_STEP))
        before = svc.shards.stats.delta_hits
        assert before == 2
        svc.shards.add_shard("shard-new")
        # chain must keep splicing after the hood reroutes
        svc.plan(lon_box(64.0, 106.0))
        assert svc.shards.stats.delta_hits == before + 1


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _props = settings(max_examples=25, deadline=None)

    class TestDriftSweepHypothesis:
        """Hypothesis-deepened drift sweep: any integral (lon, level,
        datetime) drift vector inside the eligibility envelope must
        splice byte-identically to cold planning."""

        @_props
        @given(lon_k=st.integers(-6, 6), lev_k=st.integers(-1, 1),
               dt_k=st.integers(-2, 2))
        def test_splice_matches_cold(self, lon_k, lev_k, dt_k):
            if lon_k == 0 and lev_k == 0 and dt_k == 0:
                return
            wc = IrregularWeatherCube(n_dates=2, times_per_day=3,
                                      n_levels=4, n_lat=24, n_lon=36)
            ex = PolytopeExtractor(wc.cube)
            dp = DeltaPlanner(wc.cube, slicer=ex.slicer)
            r_old = lon_box(34.0, 76.0, level=1.0, datetime=2 * DT_STEP)
            r_new = lon_box(34.0 + lon_k * LON_STEP,
                            76.0 + lon_k * LON_STEP,
                            level=1.0 + lev_k,
                            datetime=(2 + dt_k) * DT_STEP)
            splice_or_fail(dp, ex, r_old, r_new, wc.cube)
