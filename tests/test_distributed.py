"""Multi-device SPMD behaviour (8 fake CPU devices via subprocess —
jax pins the device count at first import, so these run out of process)
— plus the consistent-hash ring that routes plan-cache keys to shards.

The SPMD classes carry the ``slow`` marker individually (JAX-compile
heavy; the fast lane runs ``-m 'not slow'``); the HashRing classes are
pure-python and run everywhere.  The hypothesis classes deepen the ring
properties when hypothesis is installed and skip cleanly otherwise.
"""

import hashlib
import json
import os
import subprocess
import sys
import textwrap
from collections import Counter
from pathlib import Path

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_spmd(body: str, n_dev: int = 8) -> dict:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_dev}"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestShardingRules:
    def test_lm_rules_specs(self):
        import jax
        import numpy as np

        from repro.configs import get_arch
        from repro.distributed import sharding as shd
        from repro.models import transformer as tf

        arch = get_arch("glm4-9b")
        import jax.numpy as jnp

        cfg = tf.TransformerConfig(name="t", vocab=160, d_model=32,
                                   n_layers=2, n_heads=4, n_kv_heads=2,
                                   d_head=8, d_ff=64)
        avals = jax.eval_shape(
            lambda k: tf.init_params(k, cfg), jax.random.PRNGKey(0))
        specs = shd.param_specs(avals, shd.lm_rules)
        flat = {"/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path): s
                for path, s in
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))[0]}
        assert flat["embed/table"] == jax.sharding.PartitionSpec(
            "model", None)
        # stacked layer weights get a leading None for the scan dim
        assert flat["groups/0/attn/wq"][0] is None
        assert "model" in flat["groups/0/attn/wq"]

    def test_sanitize_drops_undivisible_and_missing(self):
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import sanitize_specs

        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        specs = {"a": P("model", "data"), "b": P(("data", "pod")),
                 "c": P("data")}
        avals = {"a": jax.ShapeDtypeStruct((7, 4), "float32"),
                 "b": jax.ShapeDtypeStruct((8, 2), "float32"),
                 "c": jax.ShapeDtypeStruct((3,), "float32")}
        out = sanitize_specs(specs, avals, mesh)
        assert out["a"] == P(None, "data")   # 'model' axis missing
        assert out["b"] == P("data")          # 'pod' dropped from tuple
        assert out["c"] == P("data")          # 3 % 1 == 0 → kept


@pytest.mark.slow
class TestSPMDExecution:
    def test_sharded_train_step_matches_single_device(self):
        res = run_spmd("""
            from repro.train.optimizer import OptimizerConfig
            from repro.train.train_state import (init_train_state,
                                                 make_train_step)
            from repro.distributed.context import mesh_context

            def loss_fn(params, batch):
                pred = batch["x"] @ params["w"]
                return jnp.mean((pred - batch["y"]) ** 2), {}

            cfg = OptimizerConfig(kind="adamw", lr=0.05,
                                  weight_decay=0.0, warmup_steps=0,
                                  total_steps=10_000)
            key = jax.random.PRNGKey(0)
            params = {"w": jax.random.normal(key, (16, 8))}
            batch = {"x": jax.random.normal(key, (32, 16)),
                     "y": jax.random.normal(key, (32, 8))}
            step = make_train_step(loss_fn, cfg)

            # single-device reference
            s0 = init_train_state(params, cfg)
            ref, _ = jax.jit(step)(s0, batch)

            mesh = jax.make_mesh((2, 4), ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2)
            P_ = P
            with mesh_context(mesh):
                sspec = {"params": {"w": NamedSharding(mesh,
                                                       P_(None, "model"))},
                         "opt": {"m": {"w": NamedSharding(mesh,
                                                          P_("data",
                                                             "model"))},
                                 "v": {"w": NamedSharding(mesh,
                                                          P_("data",
                                                             "model"))},
                                 "step": NamedSharding(mesh, P_())}}
                bspec = {"x": NamedSharding(mesh, P_("data", None)),
                         "y": NamedSharding(mesh, P_("data", None))}
                s1 = init_train_state(params, cfg)
                out, _ = jax.jit(step, in_shardings=(sspec, bspec))(
                    s1, batch)
            err = float(jnp.max(jnp.abs(out["params"]["w"]
                                        - ref["params"]["w"])))
            print(json.dumps({"err": err}))
        """)
        assert res["err"] < 1e-5

    def test_quantized_psum_shard_map(self):
        res = run_spmd("""
            from functools import partial
            from repro.distributed.compression import quantized_psum

            mesh = jax.make_mesh((8,), ("data",),
                axis_types=(jax.sharding.AxisType.Auto,))
            x = jnp.arange(64.0).reshape(8, 8) / 7.0

            @partial(jax.shard_map, mesh=mesh,
                     in_specs=P("data", None), out_specs=P("data", None))
            def f(xs):
                return quantized_psum(xs, "data")[None] * jnp.ones(
                    (1, 1)) + xs * 0

            out = f(x)
            exact = jnp.sum(x, axis=0)
            err = float(jnp.max(jnp.abs(out[0] - exact)))
            rel = err / float(jnp.max(jnp.abs(exact)))
            print(json.dumps({"rel": rel}))
        """)
        assert res["rel"] < 0.05   # int8 quantisation error bound

    def test_row_sharded_embedding_lookup(self):
        """Row-sharded table + psum lookup == dense lookup (the recsys
        table sharding pattern)."""
        res = run_spmd("""
            from repro.distributed.context import mesh_context
            mesh = jax.make_mesh((8,), ("model",),
                axis_types=(jax.sharding.AxisType.Auto,))
            table = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
            ids = jnp.asarray([0, 5, 63, 17, 33])
            ref = table[ids]
            tsh = jax.device_put(table,
                                 NamedSharding(mesh, P("model", None)))
            with mesh_context(mesh):
                out = jax.jit(lambda t, i: jnp.take(t, i, axis=0))(
                    tsh, ids)
            err = float(jnp.max(jnp.abs(out - ref)))
            print(json.dumps({"err": err}))
        """)
        assert res["err"] == 0.0

    def test_elastic_checkpoint_reshard(self):
        """Save on a (4,2) mesh, restore onto (2,4) — elastic restore."""
        res = run_spmd("""
            import tempfile
            from repro.train.checkpoint import (restore_checkpoint,
                                                save_checkpoint)
            w = jnp.arange(256.0).reshape(16, 16)
            m1 = jax.make_mesh((4, 2), ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2)
            m2 = jax.make_mesh((2, 4), ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2)
            ws = jax.device_put(w, NamedSharding(m1, P("data", "model")))
            with tempfile.TemporaryDirectory() as d:
                save_checkpoint(d, 1, {"w": ws})
                out = restore_checkpoint(
                    d, 1, {"w": jax.ShapeDtypeStruct((16, 16),
                                                     "float32")},
                    {"w": NamedSharding(m2, P("data", "model"))})
            err = float(jnp.max(jnp.abs(out["w"] - w)))
            nsh = len(out["w"].sharding.device_set)
            print(json.dumps({"err": err, "ndev": nsh}))
        """)
        assert res["err"] == 0.0
        assert res["ndev"] == 8


@pytest.mark.slow
class TestDryRunEntry:
    def test_dryrun_cheap_cell_subprocess(self, tmp_path):
        """E2E guard on the dry-run entrypoint: one cheap cell must
        lower + compile on the production 256-chip mesh."""
        env = dict(os.environ, PYTHONPATH=SRC)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "deepfm", "--shape", "serve_p99",
             "--out", str(tmp_path / "d.json")],
            env=env, capture_output=True, text=True, timeout=560,
            cwd=str(Path(SRC).parent))
        assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
        rec = json.loads((tmp_path / "d.json").read_text())
        cell = rec["deepfm|serve_p99|sp"]
        assert cell["ok"]
        assert cell["n_devices"] == 256
        assert cell["cost"]["flops_per_device"] > 0


# ---------------------------------------------------------------------------
# Consistent-hash routing (plan-cache sharding, DESIGN.md §7)
# ---------------------------------------------------------------------------

def _keys(n, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [hashlib.sha256(rng.bytes(16)).hexdigest() for _ in range(n)]


class TestHashRing:
    def test_route_is_deterministic_and_member(self):
        from repro.distributed.sharding import HashRing

        ring = HashRing(["a", "b", "c", "d"])
        for k in _keys(100, 0):
            owner = ring.route(k)
            assert owner in ring.nodes
            assert ring.route(k) == owner

    def test_key_point_is_64bit_prefix(self):
        from repro.distributed.sharding import (PREFIX_HEX, RING_SPACE,
                                                key_point)

        k = hashlib.sha256(b"polytope").hexdigest()
        assert key_point(k) == int(k[:PREFIX_HEX], 16)
        assert 0 <= key_point(k) < RING_SPACE

    def test_balance_within_tolerance(self):
        from repro.distributed.sharding import HashRing

        ring = HashRing([f"s{i}" for i in range(4)], replicas=64)
        counts = Counter(ring.route(k) for k in _keys(4000, 7))
        for node in ring.nodes:
            share = counts[node] / 4000
            assert 0.10 <= share <= 0.45, f"{node}: {share:.3f}"

    def test_add_node_minimal_directed_remap(self):
        from repro.distributed.sharding import HashRing

        keys = _keys(4000, 11)
        ring = HashRing([f"s{i}" for i in range(4)], replicas=64)
        before = {k: ring.route(k) for k in keys}
        ring.add_node("s4")
        moved = [k for k in keys if ring.route(k) != before[k]]
        frac = len(moved) / len(keys)
        assert 0.10 <= frac <= 0.35, f"remap fraction {frac:.3f}"
        # keys only ever move TO the added node
        assert all(ring.route(k) == "s4" for k in moved)

    def test_remove_node_only_moves_orphans(self):
        from repro.distributed.sharding import HashRing

        keys = _keys(1000, 13)
        ring = HashRing([f"s{i}" for i in range(5)], replicas=64)
        before = {k: ring.route(k) for k in keys}
        ring.remove_node("s2")
        for k in keys:
            if before[k] != "s2":
                assert ring.route(k) == before[k]
            else:
                assert ring.route(k) != "s2"

    def test_topology_errors(self):
        from repro.distributed.sharding import HashRing

        ring = HashRing(["a"])
        with pytest.raises(ValueError):
            ring.add_node("a")
        with pytest.raises(KeyError):
            ring.remove_node("zz")
        with pytest.raises(ValueError):
            HashRing(replicas=0)
        empty = HashRing()
        with pytest.raises(RuntimeError):
            empty.route("ff" * 32)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestHashRingProperties:
    """Property-style deepening of the consistent-hashing contract."""

    if HAVE_HYPOTHESIS:
        key_lists = st.lists(
            st.binary(min_size=4, max_size=32), min_size=100,
            max_size=300, unique=True).map(
                lambda bs: [hashlib.sha256(b).hexdigest() for b in bs])

        @given(n_nodes=st.integers(2, 8), keys=key_lists)
        @settings(max_examples=25, deadline=None)
        def test_balance(self, n_nodes, keys):
            from repro.distributed.sharding import HashRing

            ring = HashRing([f"s{i}" for i in range(n_nodes)],
                            replicas=64)
            counts = Counter(ring.route(k) for k in keys)
            cap = min(1.0, 3.5 / n_nodes)
            assert max(counts.values()) / len(keys) <= cap

        @given(n_nodes=st.integers(2, 8), keys=key_lists)
        @settings(max_examples=25, deadline=None)
        def test_add_moves_keys_only_to_new_node(self, n_nodes, keys):
            from repro.distributed.sharding import HashRing

            ring = HashRing([f"s{i}" for i in range(n_nodes)],
                            replicas=64)
            before = {k: ring.route(k) for k in keys}
            ring.add_node("added")
            moved = [k for k in keys if ring.route(k) != before[k]]
            assert all(ring.route(k) == "added" for k in moved)
            # minimal remap: well under a full reshuffle
            assert len(moved) / len(keys) <= min(1.0,
                                                 4.0 / (n_nodes + 1))

        @given(n_nodes=st.integers(3, 8), keys=key_lists,
               victim=st.integers(0, 7))
        @settings(max_examples=25, deadline=None)
        def test_remove_never_touches_survivors_keys(self, n_nodes,
                                                     keys, victim):
            from repro.distributed.sharding import HashRing

            node = f"s{victim % n_nodes}"
            ring = HashRing([f"s{i}" for i in range(n_nodes)],
                            replicas=64)
            before = {k: ring.route(k) for k in keys}
            ring.remove_node(node)
            for k in keys:
                if before[k] != node:
                    assert ring.route(k) == before[k]
