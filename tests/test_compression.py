import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (compress_grads,
                                           dequantize_int8,
                                           init_error_feedback,
                                           quantize_int8)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_state import init_train_state, make_train_step


class TestQuantize:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 3, (128,)).astype(np.float32))
        q, scale = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
        assert err.max() <= float(scale) * 0.5 + 1e-6

    def test_zero_tensor(self):
        q, scale = quantize_int8(jnp.zeros(16))
        np.testing.assert_array_equal(np.asarray(q), 0)


class TestErrorFeedback:
    def test_ef_carries_residual(self):
        grads = {"w": jnp.asarray([1e-4, 2.0, -3.0])}
        state = {"ef": init_error_feedback(grads)}
        cg, state = compress_grads(grads, state)
        # residual = original - quantised
        resid = np.asarray(state["ef"]["w"])
        np.testing.assert_allclose(
            np.asarray(cg["w"]) + resid, np.asarray(grads["w"]),
            rtol=1e-6)

    def test_training_converges_with_compression(self):
        cfg = OptimizerConfig(kind="adamw", lr=0.05, weight_decay=0.0,
                              warmup_steps=0, total_steps=1000)

        def loss_fn(params, batch):
            return jnp.mean(jnp.square(params["w"] - 2.0)), {}

        params = {"w": jnp.ones((16, 16)) * 9.0}
        state = init_train_state(params, cfg)
        state["ef"] = init_error_feedback(params)
        step = jax.jit(make_train_step(loss_fn, cfg,
                                       compressor=compress_grads))
        for _ in range(200):
            state, metrics = step(state, jnp.zeros(()))
        np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                                   2.0, atol=0.2)

    def test_compression_beats_naive_quantised_sgd(self):
        """Without EF, tiny gradients vanish under int8; with EF they
        accumulate — the canonical failure case."""
        lr = 0.1
        w_ef = jnp.asarray(1.0)
        ef = jnp.asarray(0.0)
        w_nf = jnp.asarray(1.0)
        for _ in range(400):
            g = 0.002 * jnp.sign(w_ef) + 2.0  # big common + small part
            q, s = quantize_int8(jnp.asarray([g + ef]))
            deq = float(dequantize_int8(q, s)[0])
            ef = (g + ef) - deq
            w_ef = w_ef - lr * 0.0  # only checking residual bookkeeping
        assert abs(float(ef)) < 1.0  # EF residual stays bounded
