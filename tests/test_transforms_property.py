"""Hypothesis differential suite for the axis-transform layer
(DESIGN.md §2.5): for *any* request, extraction through a transformed
axis is byte-identical to extraction against the explicitly
materialized (unrolled/merged/remapped) datacube, and seam-straddling
cyclic requests shifted by whole periods share one canonical hash.

Seeded-rng versions of the same invariants always run in
tests/test_transforms.py; this module deepens the search when
hypothesis is installed and skips cleanly when it is not.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Box, Request, Select, Slicer, Span,
                        Union)  # noqa: E402
from repro.dataplane.weather import IrregularWeatherCube  # noqa: E402

settings.register_profile("repro", deadline=None, max_examples=30)
settings.load_profile("repro")

PERIOD = 360.0

# One shared small cube per module: construction is pure, plans are
# independent per request.
IWC = IrregularWeatherCube(n_dates=2, times_per_day=3, n_levels=2,
                           n_lat=16, n_lon=24)
TDC = IWC.cube
MAT = IWC.materialized()
DATA = IWC.field_data(seed=99)


def split_lon_span(lo, hi, period=PERIOD):
    if hi - lo >= period:
        return [(0.0, period)]
    k = np.floor(lo / period)
    lo, hi = lo - k * period, hi - k * period
    if hi < period:
        return [(lo, hi)]
    # hi lands on/over the seam: the wrapped tail [0, hi-period] is part
    # of the interval (hi == period includes stored value 0 exactly)
    return [(lo, period), (0.0, hi - period)]


def assert_byte_identical(req_t, req_m):
    plan_t, _ = Slicer(TDC).extract_plan(req_t)
    plan_m, _ = Slicer(MAT).extract_plan(req_m)
    np.testing.assert_array_equal(np.sort(plan_t.offsets),
                                  np.sort(plan_m.offsets))
    np.testing.assert_array_equal(DATA[np.sort(plan_t.offsets)],
                                  DATA[np.sort(plan_m.offsets)])


finite = dict(allow_nan=False, allow_infinity=False)


class TestDifferentialProperties:
    @given(lo=st.floats(-800.0, 800.0, **finite),
           width=st.floats(0.0, 700.0, **finite),
           lat_lo=st.floats(-90.0, 80.0, **finite),
           lat_w=st.floats(0.0, 60.0, **finite))
    def test_cyclic_span_matches_manual_seam_split(self, lo, width,
                                                   lat_lo, lat_w):
        hi = lo + width
        shapes = [Select("datetime", [0.0]), Select("level", [0.0]),
                  Span("lat", lat_lo, lat_lo + lat_w)]
        req_t = Request(shapes + [Span("lon", lo, hi)])
        req_m = Request(shapes + [Union([Span("lon", a, b) for a, b in
                                         split_lon_span(lo, hi)])])
        assert_byte_identical(req_t, req_m)

    @given(t0=st.floats(-1e4, 2 * 86400.0, **finite),
           dt=st.floats(0.0, 86400.0, **finite),
           la0=st.floats(-90.0, 85.0, **finite),
           law=st.floats(0.0, 90.0, **finite),
           lo0=st.floats(0.0, 300.0, **finite),
           low=st.floats(0.0, 59.0, **finite))
    def test_merged_mapped_box_matches_materialized(self, t0, dt, la0, law,
                                                    lo0, low):
        # in-period lon: the merged/mapped axes are the moving parts here
        req = Request([Span("datetime", t0, t0 + dt),
                       Box(("lat", "lon"), [la0, lo0],
                           [la0 + law, lo0 + low])])
        assert_byte_identical(req, req)

    @given(level=st.sampled_from([0.0, 1.0]),
           lat=st.floats(-89.0, 89.0, **finite),
           lon=st.floats(-360.0, 720.0, **finite))
    def test_point_select_matches_materialized_in_period(self, level, lat,
                                                         lon):
        # Select snapping wraps on the transformed cube; fold lon into
        # the stored period so both cubes snap identically, then demand
        # byte identity.
        lon_c = lon % PERIOD
        # avoid the seam neighbourhood where cyclic snapping (correctly)
        # differs from plain nearest-on-axis
        step = PERIOD / IWC.n_lon
        if min(lon_c, PERIOD - lon_c) < step:
            lon_c = 3 * step
        req = Request([Select("datetime", [0.0]), Select("level", [level]),
                       Select("lat", [lat]), Select("lon", [lon_c])])
        assert_byte_identical(req, req)


class TestSeamHashProperties:
    @given(lo=st.floats(-360.0, 360.0, **finite),
           width=st.floats(0.5, 350.0, **finite),
           k=st.integers(-3, 3))
    def test_period_shift_preserves_hash(self, lo, width, k):
        p = {"lon": PERIOD}
        r0 = Request([Span("lon", lo, lo + width)])
        rk = Request([Span("lon", lo + k * PERIOD, lo + width + k * PERIOD)])
        assert r0.canonical_hash(periods=p) == rk.canonical_hash(periods=p)

    @given(lo=st.floats(-180.0, 180.0, **finite),
           width=st.floats(0.5, 350.0, **finite),
           eps=st.floats(1.0, 5.0, **finite))
    def test_distinct_widths_stay_distinct(self, lo, width, eps):
        p = {"lon": PERIOD}
        r0 = Request([Span("lon", lo, lo + width)])
        r1 = Request([Span("lon", lo, lo + width + eps)])
        assert r0.canonical_hash(periods=p) != r1.canonical_hash(periods=p)
