"""Architecture definitions: model config + abstract inputs + sharding +
step functions for every (arch × shape) cell.

An :class:`ArchDef` answers, for each assigned input shape:
  * ``lowering(shape, mesh)`` — the function to ``jit(...).lower()``,
    its abstract arguments (ShapeDtypeStructs — never allocated), and
    the PartitionSpec trees, exactly what the multi-pod dry-run needs;
  * ``smoke_batch(shape)`` — small concrete arrays for CPU smoke tests.

Three families: "lm" (5 transformer archs × train/prefill/decode/500k),
"gnn" (NequIP × 4 graph regimes), "recsys" (4 archs × 4 serving
regimes).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import nequip as nq
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.train_state import make_train_step


@dataclass
class Lowering:
    fn: Callable
    args: tuple                 # abstract avals (pytrees of SDS)
    in_specs: tuple             # matching PartitionSpec pytrees
    donate: tuple = ()
    kind: str = "train"         # train | prefill | decode | serve


@dataclass
class ArchDef:
    arch_id: str
    family: str                 # lm | gnn | recsys
    shapes: tuple[str, ...]
    lowering: Callable[[str, Mesh], Lowering]
    smoke: Callable[[], dict]   # returns {fn, args…} run on CPU
    describe: Callable[[], dict]
    # Cost probes: XLA cost_analysis counts while-loop bodies once, so
    # scanned-layer models are measured via small *unrolled* probe
    # lowerings and linearly extrapolated (see launch/roofline.py).
    # probes(shape, mesh) → {name: Lowering}; correction() → meta dict.
    probes: Callable[[str, Mesh], dict] | None = None
    correction: Callable[[], dict] | None = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def dp(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


# =====================================================================
# LM family
# =====================================================================
LM_SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _abstract_params(init_fn, cfg):
    return jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.PRNGKey(0))


def _opt_specs(opt_kind: str, params_avals, pspecs):
    """Spec tree for the optimizer state, mirroring its structure."""
    if opt_kind == "adamw":
        mv = shd.opt_state_specs(pspecs, params_avals)
        return {"m": mv, "v": mv, "step": P()}
    # adafactor: vr drops last dim, vc drops second-to-last
    def fspec(spec, leaf):
        shape = np.shape(leaf)
        spec = shd.add_data_axis(spec, shape)
        dims = list(spec) + [None] * (len(shape) - len(spec))
        if len(shape) >= 2:
            return {"vr": P(*dims[:-1]),
                    "vc": P(*dims[:-2], dims[-1])}
        return {"v": P(*dims)}

    f = jax.tree.map(fspec, pspecs, params_avals,
                     is_leaf=lambda x: isinstance(x, P))
    return {"f": f, "step": P()}


def _abstract_opt(opt_cfg: OptimizerConfig, params_avals):
    opt_init, _ = make_optimizer(opt_cfg)
    return jax.eval_shape(opt_init, params_avals)


LM_ACCUM = 8   # gradient-accumulation microbatches for train shapes


def lm_arch(arch_id: str, cfg: tf.TransformerConfig,
            smoke_cfg: tf.TransformerConfig, opt_cfg: OptimizerConfig,
            fsdp: bool = True, accum: int = LM_ACCUM) -> ArchDef:
    base_rules = shd.lm_rules
    rules = shd.fsdp_rules(base_rules) if fsdp else base_rules

    def _lower(shape: str, mesh: Mesh, mcfg: tf.TransformerConfig,
               probe: bool = False) -> Lowering:
        info = LM_SHAPES[shape]
        b, s = info["batch"], info["seq"]
        dpa = dp(mesh)

        params_avals = _abstract_params(tf.init_params, mcfg)
        pspecs = shd.param_specs(params_avals, rules)

        if info["kind"] == "train":
            if probe:
                # one unrolled microbatch, grads only (no optimizer)
                mb = max(b // accum, 1)
                batch_avals = {"tokens": _sds((mb, s), jnp.int32),
                               "labels": _sds((mb, s), jnp.int32)}
                bspecs = {"tokens": P(dpa, None), "labels": P(dpa, None)}

                def fn(params, batch):
                    def loss(p):
                        l, _ = tf.loss_fn(p, mcfg, batch["tokens"],
                                          batch["labels"])
                        return l

                    return jax.value_and_grad(loss)(params)

                return Lowering(fn, (params_avals, batch_avals),
                                (pspecs, bspecs), kind="train")

            opt_avals = _abstract_opt(opt_cfg, params_avals)
            ospecs = _opt_specs(opt_cfg.kind, params_avals, pspecs)
            state_avals = {"params": params_avals, "opt": opt_avals}
            sspecs = {"params": pspecs, "opt": ospecs}
            batch_avals = {"tokens": _sds((b, s), jnp.int32),
                           "labels": _sds((b, s), jnp.int32)}
            bspecs = {"tokens": P(dpa, None), "labels": P(dpa, None)}

            def loss(params, batch):
                return tf.loss_fn(params, mcfg, batch["tokens"],
                                  batch["labels"])

            step = make_train_step(loss, opt_cfg, accum_steps=accum)
            return Lowering(step, (state_avals, batch_avals),
                            (sspecs, bspecs), donate=(0,), kind="train")

        if info["kind"] == "prefill":
            tok_avals = _sds((b, s), jnp.int32)

            def fn(params, tokens):
                return tf.prefill(params, mcfg, tokens, max_seq=s)

            return Lowering(fn, (params_avals, tok_avals),
                            (pspecs, P(dpa, None)), kind="prefill")

        # decode: one new token against an S-token cache
        cache_avals = jax.eval_shape(lambda: tf.init_cache(mcfg, b, s))
        if b == 1:
            seq_ax = tuple(a for a in ("data", "model")
                           if a in mesh.axis_names)
            cspec_batch, cspec_seq = None, seq_ax
        else:
            cspec_batch, cspec_seq = dpa, "model"

        def cache_spec(leaf):
            # (L, B, S, …)
            extra = (None,) * (len(leaf.shape) - 3)
            return P(None, cspec_batch, cspec_seq, *extra)

        cspecs = jax.tree.map(cache_spec, cache_avals)
        tok_aval = _sds((b,), jnp.int32)
        pos_aval = _sds((b,), jnp.int32)
        tspec = P(dpa) if b > 1 else P()

        def fn(params, caches, token, position):
            return tf.decode_step(params, mcfg, caches, token, position)

        return Lowering(fn, (params_avals, cache_avals, tok_aval,
                             pos_aval),
                        (pspecs, cspecs, tspec, tspec),
                        donate=(1,), kind="decode")

    def lowering(shape: str, mesh: Mesh) -> Lowering:
        return _lower(shape, mesh, cfg)

    def _probe_cfg(g0: int, g1: int) -> tf.TransformerConfig:
        """Probe with g0 layers in group 0 (+ g1 in group 1 if the arch
        has two groups).  Single-group archs use g0 as their count.
        q_chunk is kept (bytes depend on it); the q-chunk loop unrolls
        under scan_unroll so cost_analysis sees every chunk."""
        if cfg.moe is not None and cfg.n_dense_layers:
            nl, ndl = g0 + g1, g0
        else:
            nl, ndl = g0, 0
        return dataclasses.replace(cfg, n_layers=nl, n_dense_layers=ndl,
                                   scan_unroll=True)

    def probes(shape: str, mesh: Mesh) -> dict:
        two_groups = cfg.moe is not None and cfg.n_dense_layers > 0
        out = {"p11": _lower(shape, mesh, _probe_cfg(1, 1), probe=True)}
        out["p21"] = _lower(shape, mesh, _probe_cfg(2, 1), probe=True)
        if two_groups:
            out["p12"] = _lower(shape, mesh, _probe_cfg(1, 2),
                                probe=True)
        return out

    def correction() -> dict:
        groups = cfg.layer_groups()
        n_params = sum(
            int(np.prod(l.shape)) for l in
            jax.tree.leaves(_abstract_params(tf.init_params, cfg)))
        return {"groups": [n for n, _ in groups],
                "two_groups": len(groups) > 1,
                "accum": accum, "opt_kind": opt_cfg.kind,
                "n_params": n_params}

    def smoke() -> dict:
        key = jax.random.PRNGKey(0)
        params = tf.init_params(key, smoke_cfg)
        toks = jax.random.randint(key, (2, 16), 0, smoke_cfg.vocab)

        def loss(params, batch):
            return tf.loss_fn(params, smoke_cfg, batch["tokens"],
                              batch["labels"])

        step = make_train_step(loss, dataclasses.replace(
            opt_cfg, warmup_steps=2, total_steps=10))
        from repro.train.train_state import init_train_state
        state = init_train_state(params, opt_cfg)
        return {"step": step, "state": state,
                "batch": {"tokens": toks, "labels": toks},
                "forward": lambda: tf.forward(params, smoke_cfg, toks)}

    def describe() -> dict:
        return {"arch": arch_id, "family": "lm",
                "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                "vocab": cfg.vocab, "moe": cfg.moe is not None}

    return ArchDef(arch_id, "lm", tuple(LM_SHAPES), lowering, smoke,
                   describe, probes=probes, correction=correction)


# =====================================================================
# GNN family (NequIP)
# =====================================================================
# Graph extents are padded up to multiples of 512 (the full device
# count) — samplers pad with masked nodes/edges anyway, and jit input
# shardings require even divisibility.  Real sizes in comments.
GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=3072, n_edges=10752, d_feat=1433,
                          n_out=7, readout="node_class"),
    # real: 2708 nodes / 10556 edges (Cora)
    "minibatch_lg": dict(n_nodes=169_984, n_edges=168_960, d_feat=602,
                         n_out=41, readout="node_class", sampled=True),
    # sampled subgraph of Reddit (232 965 / 114 615 892): 1024 seeds,
    # fanout 15-10 → 1024+15 360+153 600 nodes, 168 960 edges (exact)
    "ogb_products": dict(n_nodes=2_449_408, n_edges=61_859_840,
                         d_feat=100, n_out=47, readout="node_class"),
    # real: 2 449 029 nodes / 61 859 140 edges
    "molecule": dict(n_nodes=4096, n_edges=8192, d_feat=16,
                     n_out=1, readout="energy", n_graphs=128,
                     forces=True),
    # real: 128 graphs × 30 nodes / 64 edges = 3840 / 8192
}


def gnn_arch(arch_id: str, base: nq.NequIPConfig,
             smoke_base: nq.NequIPConfig,
             opt_cfg: OptimizerConfig) -> ArchDef:
    def shape_cfg(shape: str) -> nq.NequIPConfig:
        info = GNN_SHAPES[shape]
        return dataclasses.replace(base, d_feat=info["d_feat"],
                                   n_out=info["n_out"],
                                   readout=info["readout"])

    def lowering(shape: str, mesh: Mesh) -> Lowering:
        info = GNN_SHAPES[shape]
        cfg = shape_cfg(shape)
        n, e = info["n_nodes"], info["n_edges"]
        axes = all_axes(mesh)

        params_avals = _abstract_params(nq.nequip_init, cfg)
        pspecs = shd.param_specs(params_avals, shd.gnn_rules)
        opt_avals = _abstract_opt(opt_cfg, params_avals)
        ospecs = _opt_specs(opt_cfg.kind, params_avals, pspecs)
        state_avals = {"params": params_avals, "opt": opt_avals}
        sspecs = {"params": pspecs, "opt": ospecs}

        batch_avals = {
            "node_feat": _sds((n, info["d_feat"]), jnp.float32),
            "positions": _sds((n, 3), jnp.float32),
            "edge_index": _sds((2, e), jnp.int32),
        }
        bspecs = {
            "node_feat": P(axes, None),
            "positions": P(axes, None),
            "edge_index": P(None, axes),
        }
        if info["readout"] == "node_class":
            batch_avals["labels"] = _sds((n,), jnp.int32)
            batch_avals["label_mask"] = _sds((n,), jnp.float32)
            bspecs["labels"] = P(axes)
            bspecs["label_mask"] = P(axes)
        else:
            ng = info["n_graphs"]
            batch_avals.update({
                "graph_ids": _sds((n,), jnp.int32),
                "energy": _sds((ng,), jnp.float32),
                "forces": _sds((n, 3), jnp.float32),
                "n_graphs": ng,
            })
            bspecs.update({"graph_ids": P(axes), "energy": P(),
                           "forces": P(axes, None), "n_graphs": None})

        def loss(params, batch):
            return nq.nequip_loss(params, cfg, batch), {}

        step = make_train_step(loss, opt_cfg)
        # n_graphs is static — close over it
        if info["readout"] == "energy":
            ng = batch_avals.pop("n_graphs")
            bspecs.pop("n_graphs")

            def loss(params, batch):
                return nq.nequip_loss(params, cfg,
                                      {**batch, "n_graphs": ng}), {}

            step = make_train_step(loss, opt_cfg)
        return Lowering(step, (state_avals, batch_avals),
                        (sspecs, bspecs), donate=(0,), kind="train")

    def smoke() -> dict:
        cfg = dataclasses.replace(smoke_base, d_feat=8, n_out=3,
                                  readout="node_class")
        key = jax.random.PRNGKey(0)
        params = nq.nequip_init(key, cfg)
        rng = np.random.default_rng(0)
        n, e = 16, 40
        batch = {
            "node_feat": jnp.asarray(rng.normal(size=(n, 8)),
                                     jnp.float32),
            "positions": jnp.asarray(rng.uniform(0, 3, (n, 3)),
                                     jnp.float32),
            "edge_index": jnp.asarray(rng.integers(0, n, (2, e)),
                                      jnp.int32),
            "labels": jnp.asarray(rng.integers(0, 3, n), jnp.int32),
            "label_mask": jnp.ones((n,), jnp.float32),
        }

        def loss(params, b):
            return nq.nequip_loss(params, cfg, b), {}

        step = make_train_step(loss, opt_cfg)
        from repro.train.train_state import init_train_state
        state = init_train_state(params, opt_cfg)
        return {"step": step, "state": state, "batch": batch,
                "forward": lambda: nq.nequip_forward(
                    params, cfg, batch["node_feat"], batch["positions"],
                    batch["edge_index"])}

    def describe() -> dict:
        return {"arch": arch_id, "family": "gnn",
                "channels": base.channels, "l_max": base.l_max,
                "n_layers": base.n_layers}

    return ArchDef(arch_id, "gnn", tuple(GNN_SHAPES), lowering, smoke,
                   describe)


# =====================================================================
# RecSys family
# =====================================================================
RECSYS_SHAPES = {
    "train_batch": dict(batch=65_536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262_144, kind="serve"),
    # 10⁶ candidates padded to 2²⁰ so the candidate axis shards evenly
    "retrieval_cand": dict(batch=1, n_cand=1_048_576, kind="retrieval"),
}


def recsys_arch(arch_id: str, kind: str, cfg: Any, smoke_cfg: Any,
                opt_cfg: OptimizerConfig) -> ArchDef:
    """kind ∈ {dlrm, deepfm, twotower, bert4rec}."""

    def make_batch_avals(shape: str, c):
        info = RECSYS_SHAPES[shape]
        b = info["batch"]
        if kind == "dlrm":
            av = {"dense": _sds((b, c.n_dense), jnp.float32),
                  "bags": _sds((b, c.n_sparse, c.bag_size), jnp.int32)}
        elif kind == "deepfm":
            av = {"bags": _sds((b, c.n_sparse, 1), jnp.int32)}
        elif kind == "twotower":
            av = {"user_ids": _sds((b,), jnp.int32),
                  "item_ids": _sds((b,), jnp.int32),
                  "item_logq": _sds((b,), jnp.float32)}
        else:  # bert4rec
            av = {"items": _sds((b, 200), jnp.int32)}
        return av

    def loss_for(c):
        if kind == "dlrm":
            return lambda p, b: (rs.dlrm_loss(p, c, b), {})
        if kind == "deepfm":
            return lambda p, b: (rs.deepfm_loss(p, c, b), {})
        if kind == "twotower":
            return lambda p, b: (rs.twotower_loss(p, c, b), {})
        return lambda p, b: (rs.bert4rec_loss(p, c, b), {})

    def init_for(c):
        return {"dlrm": rs.dlrm_init, "deepfm": rs.deepfm_init,
                "twotower": rs.twotower_init,
                "bert4rec": rs.bert4rec_init}[kind]

    def lowering(shape: str, mesh: Mesh) -> Lowering:
        info = RECSYS_SHAPES[shape]
        b = info["batch"]
        dpa = dp(mesh)
        init = init_for(cfg)
        params_avals = _abstract_params(init, cfg)
        pspecs = shd.param_specs(params_avals, shd.recsys_rules)

        if info["kind"] == "train":
            opt_avals = _abstract_opt(opt_cfg, params_avals)
            ospecs = _opt_specs(opt_cfg.kind, params_avals, pspecs)
            state_avals = {"params": params_avals, "opt": opt_avals}
            sspecs = {"params": pspecs, "opt": ospecs}
            batch_avals = make_batch_avals(shape, cfg)
            bspecs = jax.tree.map(
                lambda a: P(dpa, *([None] * (len(a.shape) - 1))),
                batch_avals)
            if kind == "dlrm" or kind == "deepfm":
                batch_avals["labels"] = _sds((b,), jnp.float32)
                bspecs["labels"] = P(dpa)
            if kind == "bert4rec":
                batch_avals["labels"] = _sds((b, 200), jnp.int32)
                batch_avals["mask"] = _sds((b, 200), jnp.float32)
                bspecs["labels"] = P(dpa, None)
                bspecs["mask"] = P(dpa, None)
            step = make_train_step(loss_for(cfg), opt_cfg)
            return Lowering(step, (state_avals, batch_avals),
                            (sspecs, bspecs), donate=(0,), kind="train")

        if info["kind"] == "retrieval":
            if kind == "twotower":
                n_cand = info["n_cand"]

                def fn(params, user_ids, cand_ids):
                    return rs.twotower_score_candidates(params, cfg,
                                                        user_ids,
                                                        cand_ids)

                return Lowering(
                    fn,
                    (params_avals, _sds((1,), jnp.int32),
                     _sds((n_cand,), jnp.int32)),
                    (pspecs, P(), P(tuple(a for a in mesh.axis_names))),
                    kind="serve")
            if kind == "bert4rec":
                def fn(params, items):
                    return rs.bert4rec_score(params, cfg, items)

                return Lowering(fn,
                                (params_avals, _sds((1, 200), jnp.int32)),
                                (pspecs, P(None, None)), kind="serve")
            # dlrm / deepfm: bulk-score 10⁶ candidate rows for one user
            b = info["n_cand"]

        batch_avals = make_batch_avals(shape, cfg) if info["kind"] != \
            "retrieval" else None
        if batch_avals is None:
            if kind == "dlrm":
                batch_avals = {"dense": _sds((b, cfg.n_dense),
                                             jnp.float32),
                               "bags": _sds((b, cfg.n_sparse,
                                             cfg.bag_size), jnp.int32)}
            else:
                batch_avals = {"bags": _sds((b, cfg.n_sparse, 1),
                                            jnp.int32)}
        bspecs = jax.tree.map(
            lambda a: P(dpa, *([None] * (len(a.shape) - 1))),
            batch_avals)

        if kind == "dlrm":
            fn = lambda p, bt: rs.dlrm_forward(p, cfg, bt["dense"],
                                               bt["bags"])
        elif kind == "deepfm":
            fn = lambda p, bt: rs.deepfm_forward(p, cfg, bt["bags"])
        elif kind == "twotower":
            fn = lambda p, bt: rs.twotower_score_candidates(
                p, cfg, bt["user_ids"], bt["item_ids"])
        else:
            fn = lambda p, bt: rs.bert4rec_score(p, cfg, bt["items"])
        return Lowering(fn, (params_avals, batch_avals),
                        (pspecs, bspecs), kind="serve")

    def smoke() -> dict:
        c = smoke_cfg
        key = jax.random.PRNGKey(0)
        params = init_for(c)(key, c)
        rng = np.random.default_rng(0)
        bsz = 8
        if kind == "dlrm":
            batch = {"dense": jnp.asarray(rng.normal(
                size=(bsz, c.n_dense)), jnp.float32),
                "bags": jnp.asarray(rng.integers(
                    0, c.rows, (bsz, c.n_sparse, c.bag_size)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, 2, bsz),
                                      jnp.float32)}
        elif kind == "deepfm":
            batch = {"bags": jnp.asarray(rng.integers(
                0, c.rows, (bsz, c.n_sparse, 1)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, 2, bsz),
                                      jnp.float32)}
        elif kind == "twotower":
            batch = {"user_ids": jnp.arange(bsz, dtype=jnp.int32),
                     "item_ids": jnp.arange(bsz, dtype=jnp.int32),
                     "item_logq": jnp.zeros((bsz,), jnp.float32)}
        else:
            items = jnp.asarray(rng.integers(0, c.vocab - 2, (bsz, 16)),
                                jnp.int32)
            batch = {"items": items, "labels": items,
                     "mask": jnp.ones((bsz, 16), jnp.float32)}
        step = make_train_step(loss_for(c), opt_cfg)
        from repro.train.train_state import init_train_state
        state = init_train_state(params, opt_cfg)
        return {"step": step, "state": state, "batch": batch}

    def describe() -> dict:
        return {"arch": arch_id, "family": "recsys", "kind": kind}

    return ArchDef(arch_id, "recsys", tuple(RECSYS_SHAPES), lowering,
                   smoke, describe)
