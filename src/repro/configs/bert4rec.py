"""BERT4Rec [arXiv:1904.06690]: bidirectional 2-block transformer over
item sequences (embed 64, 2 heads, seq 200), cloze objective.

The item vocabulary is sized 10⁶ so the ``retrieval_cand`` shape
(scoring 10⁶ candidates) is the model's own softmax head — noted in
DESIGN.md §Arch-applicability."""

from repro.models.recsys import bert4rec_config
from repro.train.optimizer import OptimizerConfig

from .common import recsys_arch

ID = "bert4rec"


def _cfg():
    import dataclasses
    # vocab = n_items + 2 = 2^20 exactly → shards evenly over 16-way TP.
    # scan_unroll: only 2 layers, so unrolled HLO keeps cost_analysis
    # exact (no while-loop undercount) at negligible compile cost.
    c = bert4rec_config(n_items=1_048_574, seq_len=200)
    return dataclasses.replace(c, scan_unroll=True)


def _smoke():
    import dataclasses
    c = bert4rec_config(n_items=500, seq_len=16)
    return dataclasses.replace(c, name=ID + "-smoke", d_model=32,
                               n_layers=2, d_ff=64, n_heads=2,
                               n_kv_heads=2, d_head=16)


def get():
    return recsys_arch(ID, "bert4rec", _cfg(), _smoke(),
                       OptimizerConfig(kind="adamw", lr=1e-3,
                                       warmup_steps=100,
                                       total_steps=300_000))
