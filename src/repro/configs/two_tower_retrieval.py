"""Two-tower retrieval [Yi et al., RecSys'19]: embed_dim 256, tower
MLPs 1024-512-256, dot scoring, in-batch sampled softmax with logQ
correction.  retrieval_cand = one query × 10⁶ candidates as a single
sharded matmul."""

from repro.models.recsys import TwoTowerConfig
from repro.train.optimizer import OptimizerConfig

from .common import recsys_arch

ID = "two-tower-retrieval"


def _cfg() -> TwoTowerConfig:
    return TwoTowerConfig(name=ID, n_users=1_000_000, n_items=1_000_000,
                          embed_dim=256, tower=(1024, 512, 256))


def _smoke() -> TwoTowerConfig:
    return TwoTowerConfig(name=ID + "-smoke", n_users=128, n_items=128,
                          embed_dim=16, tower=(32, 16))


def get():
    return recsys_arch(ID, "twotower", _cfg(), _smoke(),
                       OptimizerConfig(kind="adamw", lr=1e-3,
                                       warmup_steps=100,
                                       total_steps=300_000))
