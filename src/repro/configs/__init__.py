"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures (5 LM + 1 GNN + 4 recsys), each exposing the
full published config, a reduced smoke config, per-shape abstract
input specs and sharding rules (see ``common.ArchDef``).
"""

from __future__ import annotations

import importlib

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "arctic-480b": "arctic_480b",
    "glm4-9b": "glm4_9b",
    "yi-34b": "yi_34b",
    "granite-3-8b": "granite_3_8b",
    "nequip": "nequip",
    "dlrm-rm2": "dlrm_rm2",
    "bert4rec": "bert4rec",
    "two-tower-retrieval": "two_tower_retrieval",
    "deepfm": "deepfm",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.get()


def all_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells — 40 total."""
    cells = []
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for shape in arch.shapes:
            cells.append((aid, shape))
    return cells
