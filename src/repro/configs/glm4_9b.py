"""GLM-4 9B [hf:THUDM/glm-4-9b]: 40L d=4096, 32-head GQA (kv=2),
d_ff 13696, vocab 151552, RoPE."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .common import lm_arch

ID = "glm4-9b"


def _cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ID, vocab=151_552, d_model=4096, n_layers=40, n_heads=32,
        n_kv_heads=2, d_head=128, d_ff=13_696,
        dtype=jnp.bfloat16, q_chunk=1024)


def _smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke", vocab=256, d_model=64, n_layers=2, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, dtype=jnp.float32,
        q_chunk=None)


def get():
    # 9B dense: pure TP within the pod (no FSDP) — AdamW states ZeRO-1.
    return lm_arch(ID, _cfg(), _smoke(),
                   OptimizerConfig(kind="adamw", lr=3e-4,
                                   warmup_steps=2000,
                                   total_steps=100_000),
                   fsdp=False)
