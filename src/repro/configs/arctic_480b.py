"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: 35L
d=7168, 56-head GQA (kv=8), dense-MoE hybrid: 128-expert top-2 MoE in
parallel with a dense residual FFN (d_ff 4864)."""

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .common import lm_arch

ID = "arctic-480b"


def _cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ID, vocab=32_000, d_model=7168, n_layers=35, n_heads=56,
        n_kv_heads=8, d_head=128, d_ff=4864,
        moe=MoEConfig(d_model=7168, d_ff=4864, n_experts=128, top_k=2,
                      n_groups=32),
        dense_residual=True, dense_d_ff=4864,
        dtype=jnp.bfloat16, q_chunk=1024)


def _smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke", vocab=256, d_model=64, n_layers=3, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=96,
        moe=MoEConfig(d_model=64, d_ff=96, n_experts=8, top_k=2,
                      capacity_factor=2.0),
        dense_residual=True, dense_d_ff=96,
        dtype=jnp.float32, q_chunk=None)


def get():
    return lm_arch(ID, _cfg(), _smoke(),
                   OptimizerConfig(kind="adafactor", lr=3e-4,
                                   warmup_steps=2000,
                                   total_steps=100_000),
                   fsdp=True)
