"""Yi-34B [arXiv:2403.04652]: 60L d=7168, 56-head GQA (kv=8),
d_ff 20480, vocab 64000 — llama-architecture."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .common import lm_arch

ID = "yi-34b"


def _cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ID, vocab=64_000, d_model=7168, n_layers=60, n_heads=56,
        n_kv_heads=8, d_head=128, d_ff=20_480, rope_theta=5_000_000.0,
        dtype=jnp.bfloat16, q_chunk=1024)


def _smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke", vocab=256, d_model=56, n_layers=2, n_heads=7,
        n_kv_heads=1, d_head=8, d_ff=160, dtype=jnp.float32,
        q_chunk=None)


def get():
    return lm_arch(ID, _cfg(), _smoke(),
                   OptimizerConfig(kind="adamw", lr=1.5e-4,
                                   warmup_steps=2000,
                                   total_steps=100_000),
                   fsdp=True)
