"""DeepFM [arXiv:1703.04247]: 39 sparse fields, embed_dim 10,
deep MLP 400-400-400, FM second-order interaction."""

from repro.models.recsys import DeepFMConfig
from repro.train.optimizer import OptimizerConfig

from .common import recsys_arch

ID = "deepfm"


def _cfg() -> DeepFMConfig:
    return DeepFMConfig(name=ID, n_sparse=39, rows=1_000_000,
                        embed_dim=10, mlp_dims=(400, 400, 400))


def _smoke() -> DeepFMConfig:
    return DeepFMConfig(name=ID + "-smoke", n_sparse=6, rows=64,
                        embed_dim=4, mlp_dims=(16, 16))


def get():
    return recsys_arch(ID, "deepfm", _cfg(), _smoke(),
                       OptimizerConfig(kind="adamw", lr=1e-3,
                                       warmup_steps=100,
                                       total_steps=300_000))
