"""DLRM RM-2 [arXiv:1906.00091]: 13 dense + 26 sparse features,
embed_dim 64, bottom MLP 13-512-256-64, top MLP 512-512-256-1, dot
interaction.  Tables: 26 × 10⁶ rows (Criteo-scale), row-sharded."""

from repro.models.recsys import DLRMConfig
from repro.train.optimizer import OptimizerConfig

from .common import recsys_arch

ID = "dlrm-rm2"


def _cfg() -> DLRMConfig:
    return DLRMConfig(name=ID, n_dense=13, n_sparse=26, rows=1_000_000,
                      embed_dim=64, bot_mlp=(512, 256, 64),
                      top_mlp=(512, 512, 256, 1), bag_size=1)


def _smoke() -> DLRMConfig:
    return DLRMConfig(name=ID + "-smoke", n_dense=13, n_sparse=4,
                      rows=128, embed_dim=8, bot_mlp=(16, 8),
                      top_mlp=(16, 1), bag_size=1)


def get():
    return recsys_arch(ID, "dlrm", _cfg(), _smoke(),
                       OptimizerConfig(kind="adamw", lr=1e-3,
                                       warmup_steps=100,
                                       total_steps=300_000))
