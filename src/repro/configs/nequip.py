"""NequIP [arXiv:2101.03164]: 5 layers, 32 channels, l_max=2, 8 Bessel
RBFs, 5 Å cutoff, E(3)-equivariant tensor products.  One trunk serves
all four assigned graph regimes (d_feat / readout vary per shape)."""

from repro.models.nequip import NequIPConfig
from repro.train.optimizer import OptimizerConfig

from .common import gnn_arch

ID = "nequip"


def _base() -> NequIPConfig:
    return NequIPConfig(name=ID, n_layers=5, channels=32, l_max=2,
                        n_rbf=8, cutoff=5.0)


def _smoke() -> NequIPConfig:
    return NequIPConfig(name=ID + "-smoke", n_layers=2, channels=8,
                        l_max=2, n_rbf=4, cutoff=5.0)


def get():
    return gnn_arch(ID, _base(), _smoke(),
                    OptimizerConfig(kind="adamw", lr=1e-3,
                                    warmup_steps=100,
                                    total_steps=50_000))
