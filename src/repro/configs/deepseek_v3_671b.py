"""DeepSeek-V3 671B [arXiv:2412.19437]: 61L d=7168, 128-head MLA,
MoE 256 experts top-8 + 1 shared (d_ff 2048), 3 leading dense layers,
multi-token prediction."""

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .common import lm_arch

ID = "deepseek-v3-671b"


def _cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ID, vocab=129_280, d_model=7168, n_layers=61, n_heads=128,
        n_kv_heads=128, d_head=128,
        d_ff=2048,
        attn_type="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        moe=MoEConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                      n_shared=1, n_groups=32),
        n_dense_layers=3, dense_d_ff=18_432,
        mtp=True, dtype=jnp.bfloat16, q_chunk=1024)


def _smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke", vocab=256, d_model=64, n_layers=4, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=64,
        attn_type="mla", q_lora_rank=32, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        moe=MoEConfig(d_model=64, d_ff=32, n_experts=8, top_k=2,
                      n_shared=1, capacity_factor=2.0),
        n_dense_layers=1, dense_d_ff=128, mtp=True,
        dtype=jnp.float32, q_chunk=None)


def get():
    # 671B params: Adafactor (factored states) + full FSDP×TP sharding.
    # accum=8: §Perf iteration 3 tried accum=4 hoping to halve FSDP
    # weight all-gathers — refuted: MoE collectives are token-
    # proportional, so totals didn't move while temp memory grew 32 GiB.
    return lm_arch(ID, _cfg(), _smoke(),
                   OptimizerConfig(kind="adafactor", lr=2.2e-4,
                                   warmup_steps=2000,
                                   total_steps=100_000),
                   fsdp=True, accum=8)
