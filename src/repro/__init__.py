"""repro — a datacube-native training/serving framework built around the
Polytope feature-extraction algorithm (Leuridan et al., 2023)."""
__version__ = "1.0.0"
