"""Training launcher: ``python -m repro.launch.train --arch <id> …``

Runs any registered architecture end-to-end on the local devices (CPU
smoke / single TPU host) or a full pod (with REPRO_COORDINATOR set):
data plane → sharded train step → fault-tolerant supervisor →
checkpoints.  ``--smoke`` selects the reduced config.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import initialize_distributed
from repro.train.fault import FaultConfig, Supervisor


def data_source_for(arch, smoke: dict, arch_id: str):
    """Step-addressable synthetic data matching the arch family."""
    family = arch.family
    batch_template = smoke["batch"]

    if family == "lm":
        from repro.dataplane.tokens import TokenCube

        vocab = int(np.asarray(
            smoke["state"]["params"]["embed"]["table"]).shape[0])
        tc = TokenCube(vocab=vocab, n_docs=32, doc_len=512)
        b, s = np.asarray(batch_template["tokens"]).shape

        def source(step):
            bt = tc.batch(step, b, s)
            return {k: jnp.asarray(v) for k, v in bt.items()}

        return source

    if family == "gnn":
        from repro.dataplane.graph import minibatch, synthetic_graph

        g = synthetic_graph(512, 8, batch_template["node_feat"].shape[1],
                            int(batch_template["labels"].max()) + 1)
        n_pad = batch_template["node_feat"].shape[0]
        e_pad = batch_template["edge_index"].shape[1]

        def source(step):
            mb = minibatch(g, 8, [4, 3], n_pad, e_pad, step=step)
            return {k: jnp.asarray(v) for k, v in mb.items()}

        return source

    # recsys: replay the smoke batch shapes with fresh synthetic data
    def source(step):
        rng = np.random.default_rng(step)
        out = {}
        for k, v in batch_template.items():
            v = np.asarray(v)
            if v.dtype.kind == "i":
                hi = max(2, int(v.max()) + 1)
                out[k] = jnp.asarray(
                    rng.integers(0, hi, v.shape).astype(v.dtype))
            else:
                out[k] = jnp.asarray(
                    (rng.random(v.shape) < 0.5).astype(v.dtype))
        return out

    return source


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    initialize_distributed()
    arch = get_arch(args.arch)
    smoke = arch.smoke()
    step_fn = jax.jit(smoke["step"])
    source = data_source_for(arch, smoke, args.arch)

    sup = Supervisor(
        FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        step_fn, source)

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)

    sup.run(smoke["state"], args.steps, on_metrics=on_metrics)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
