"""Production meshes.

TPU v5e pod = 16 × 16 = 256 chips; multi-pod adds an outer "pod" axis
(data-parallel across DCI).  ``make_production_mesh`` is a function —
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small meshes for tests / CPU smoke runs."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def initialize_distributed() -> None:
    """Multi-host bring-up (real cluster entrypoint).

    On a real TPU pod each host calls this before any jax op; the
    coordinator address comes from the launch scripts
    (``launch/scripts/launch_pod.sh``).  On a single host it is a no-op.
    """
    import os

    if os.environ.get("REPRO_COORDINATOR"):
        jax.distributed.initialize(
            coordinator_address=os.environ["REPRO_COORDINATOR"],
            num_processes=int(os.environ.get("REPRO_NUM_PROCESSES", "1")),
            process_id=int(os.environ.get("REPRO_PROCESS_ID", "0")))
