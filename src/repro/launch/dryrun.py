import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell:
  jit(step).lower(*abstract_args).compile()
on the production meshes — single-pod (16 data × 16 model = 256 chips)
and multi-pod (2 pods × 256 = 512 chips) — using 512 placeholder host
devices.  Nothing is allocated (ShapeDtypeStruct inputs); success plus
``memory_analysis()`` proves the sharded program exists and fits.

Per cell we record: per-device memory stats, cost_analysis FLOPs/bytes
(XLA reports these per device post-SPMD), and the collective-op byte
totals parsed from the optimized HLO — the inputs to EXPERIMENTS.md
§Roofline.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the optimized HLO."""
    out = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        for coll in _COLLECTIVES:
            # "%op = TYPE collective-name(" — start-instruction only
            if f" {coll}(" in s and "=" in s:
                lhs, rhs = s.split("=", 1)
                type_part = rhs.strip().split(f" {coll}(")[0]
                b = _shape_bytes(type_part)
                out[coll]["bytes"] += b
                out[coll]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def run_cell(arch_id: str, shape: str, multi_pod: bool,
             extra: dict | None = None, probe: str | None = None) -> dict:
    arch = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if probe:
        low = arch.probes(shape, mesh)[probe]
    else:
        low = arch.lowering(shape, mesh)

    from repro.distributed.sharding import sanitize_specs

    def shardings(spec_tree, aval_tree):
        spec_tree = sanitize_specs(spec_tree, aval_tree, mesh)
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else
            (s if s is None else NamedSharding(mesh, s)),
            spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)

    in_shardings = tuple(shardings(s, a) for s, a in
                         zip(low.in_specs, low.args))
    from repro.distributed.context import mesh_context
    with mesh_context(mesh):
        jitted = jax.jit(low.fn, in_shardings=in_shardings,
                         donate_argnums=low.donate)
        lowered = jitted.lower(*low.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)

    rec = {
        "arch": arch_id, "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": int(mesh.devices.size),
        "kind": low.kind,
        "probe": probe,
        "correction": (arch.correction() if (arch.correction and
                                             not probe) else None),
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "cost": {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_accessed_per_device": float(
                cost.get("bytes accessed", 0.0)),
        },
        "collectives": colls,
    }
    if extra:
        rec.update(extra)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already recorded in --out")
    ap.add_argument("--probes", action="store_true",
                    help="also run the unrolled cost probes (single-pod)")
    args = ap.parse_args()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results: dict[str, dict] = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    if args.all:
        cells = []
        for aid in ARCH_IDS:
            arch = get_arch(aid)
            for shape in arch.shapes:
                cells.append((aid, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0

    def one(key, aid, shape, mp, probe=None):
        nonlocal n_fail
        if args.resume and results.get(key, {}).get("ok"):
            print(f"[skip] {key}", flush=True)
            return
        print(f"[dryrun] {key} ...", flush=True)
        try:
            rec = run_cell(aid, shape, mp, probe=probe)
            print(f"  ok: compile={rec['compile_s']}s "
                  f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                  f"coll={rec['collectives']['total_bytes']:.3e}B",
                  flush=True)
            print(f"  memory/dev: args="
                  f"{rec['memory']['argument_bytes']/2**30:.2f}GiB "
                  f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB",
                  flush=True)
        except Exception as e:
            rec = {"arch": aid, "shape": shape, "probe": probe,
                   "mesh": "pod2x16x16" if mp else "pod16x16",
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            n_fail += 1
            print(f"  FAIL: {rec['error'][:200]}", flush=True)
        results[key] = rec
        out_path.write_text(json.dumps(results, indent=1))

    for aid, shape in cells:
        for mp in meshes:
            key = f"{aid}|{shape}|{'mp' if mp else 'sp'}"
            one(key, aid, shape, mp)
        if args.probes and get_arch(aid).probes is not None:
            mesh = make_production_mesh()
            for pname in get_arch(aid).probes(shape, mesh):
                one(f"{aid}|{shape}|sp|probe:{pname}", aid, shape,
                    False, probe=pname)
    print(f"done: {len(cells) * len(meshes)} cells, {n_fail} failures",
          flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
