"""Serving launchers.

Two modes:

* ``lm``      — continuous-batching LM engine over the paged KV cache:
                  python -m repro.launch.serve --mode lm --arch glm4-9b
* ``extract`` — polytope extraction service under a Zipfian request mix
  (the production pattern: a few hot crops dominate traffic), serving
  plans from the LRU plan cache (DESIGN.md §4):
                  python -m repro.launch.serve --mode extract --requests 512
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_lm(args) -> None:
    import importlib

    import jax

    from repro.configs import _MODULES
    from repro.models.transformer import init_params
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    mod = importlib.import_module(f"repro.configs.{_MODULES[args.arch]}")
    if not hasattr(mod, "_smoke"):
        raise SystemExit(f"{args.arch} has no LM smoke config")
    cfg = mod._smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, EngineConfig(
        max_batch=4, max_seq=128, page_size=16, n_pages=256))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24)
                                ).astype(np.int32),
            max_new_tokens=args.max_new_tokens))
    done = engine.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    print(f"KV pool utilization at end: {engine.pager.utilization:.0%}")


def run_extract(args) -> None:
    """Closed-loop Zipfian load against the sharded service: ``--threads``
    clients submit through one :class:`AdmissionQueue` (so duplicate hot
    crops coalesce across callers inside each arrival window), and the
    per-request latency distribution lands in ``BENCH_serve.json``."""
    import json
    import threading

    from repro.dataplane.weather import WeatherCube, request_population
    from repro.serve.sharded import AdmissionQueue, ShardedExtractionService

    wc = WeatherCube(n=args.grid_n, n_times=4, n_levels=4)
    data = wc.field_data()
    svc = ShardedExtractionService(
        wc.cube, shards=args.shards,
        capacity_per_shard=args.cache_capacity)
    population = request_population(wc)

    if args.zipf_s <= 1.0:
        raise SystemExit("--zipf-s must be > 1 (Zipf exponent)")
    rng = np.random.default_rng(args.seed)
    ranks = np.minimum(rng.zipf(args.zipf_s, size=args.requests) - 1,
                       len(population) - 1)
    per_thread = np.array_split(ranks, max(args.threads, 1))
    latencies = [np.empty(0)] * len(per_thread)
    barrier = threading.Barrier(len(per_thread) + 1)

    def client(tid: int, my_ranks: np.ndarray, queue: AdmissionQueue):
        lat = np.empty(len(my_ranks))
        barrier.wait()
        for i, r in enumerate(my_ranks):
            t0 = time.perf_counter()
            queue.extract(population[int(r)], timeout=60)
            lat[i] = time.perf_counter() - t0
        latencies[tid] = lat

    with AdmissionQueue(svc, flat_data=data,
                        window_s=args.window_ms / 1e3) as queue:
        threads = [threading.Thread(target=client, args=(i, tr, queue))
                   for i, tr in enumerate(per_thread)]
        for t in threads:
            t.start()
        barrier.wait()
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        adm = queue.snapshot()

    lat_ms = np.concatenate(latencies) * 1e3
    if not len(lat_ms):  # --requests 0: an empty but schema-valid row
        lat_ms = np.zeros(1)
    s = svc.stats
    row = {
        "scenario": f"zipf{args.zipf_s}-grid{args.grid_n}",
        "requests": int(len(ranks)),
        "threads": int(len(per_thread)),
        "shards": int(args.shards),
        "window_ms": float(args.window_ms),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "req_per_s": float(len(ranks) / dt) if dt else 0.0,
        "hit_rate": float(s.hit_rate),
        "coalescing_factor": float(adm.coalescing_factor),
    }
    with open(args.bench_out, "w") as fh:
        json.dump({"bench": "serve", "rows": [row]}, fh, indent=1)

    print(f"served {len(ranks)} requests from {len(per_thread)} threads "
          f"in {dt:.2f}s ({row['req_per_s']:.0f} req/s)")
    print(f"latency p50 {row['p50_ms']:.2f}ms / p99 {row['p99_ms']:.2f}ms")
    print(f"plan cache: {s.hits} hits / {s.misses} misses "
          f"(+{s.batch_dedup} batch-dedup) = {s.hit_rate:.0%} hit rate, "
          f"{s.evictions} evictions across {args.shards} shards")
    print(f"admission: {adm.windows} windows (max {adm.window_max}), "
          f"{adm.coalesced} coalesced, "
          f"factor {adm.coalescing_factor:.2f}x")
    print(f"planning {s.plan_time_s:.2f}s, shared gather "
          f"{s.gather_time_s:.2f}s, read sharing {s.sharing_factor:.2f}x")
    print(f"wrote {args.bench_out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "extract"], default="lm")
    ap.add_argument("--requests", type=int, default=8)
    # lm mode
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    # extract mode
    ap.add_argument("--grid-n", type=int, default=32)
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--cache-capacity", type=int, default=256)
    ap.add_argument("--zipf-s", type=float, default=1.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bench-out", default="BENCH_serve.json")
    args = ap.parse_args()

    if args.mode == "extract":
        run_extract(args)
    else:
        from repro.configs import ARCH_IDS

        if args.arch not in ARCH_IDS:
            raise SystemExit(f"unknown arch {args.arch}")
        run_lm(args)


if __name__ == "__main__":
    main()
