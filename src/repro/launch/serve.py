"""Serving launchers.

Two modes:

* ``lm``      — continuous-batching LM engine over the paged KV cache:
                  python -m repro.launch.serve --mode lm --arch glm4-9b
* ``extract`` — polytope extraction service under a Zipfian request mix
  (the production pattern: a few hot crops dominate traffic), serving
  plans from the LRU plan cache (DESIGN.md §4):
                  python -m repro.launch.serve --mode extract --requests 512
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_lm(args) -> None:
    import importlib

    import jax

    from repro.configs import _MODULES
    from repro.models.transformer import init_params
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    mod = importlib.import_module(f"repro.configs.{_MODULES[args.arch]}")
    if not hasattr(mod, "_smoke"):
        raise SystemExit(f"{args.arch} has no LM smoke config")
    cfg = mod._smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, EngineConfig(
        max_batch=4, max_seq=128, page_size=16, n_pages=256))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24)
                                ).astype(np.int32),
            max_new_tokens=args.max_new_tokens))
    done = engine.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    print(f"KV pool utilization at end: {engine.pager.utilization:.0%}")


def run_extract(args) -> None:
    from repro.dataplane.weather import WeatherCube, request_population
    from repro.serve.extraction import ExtractionService

    wc = WeatherCube(n=args.grid_n, n_times=4, n_levels=4)
    data = wc.field_data()
    svc = ExtractionService(wc.cube, capacity=args.cache_capacity)
    population = request_population(wc)

    if args.zipf_s <= 1.0:
        raise SystemExit("--zipf-s must be > 1 (Zipf exponent)")
    rng = np.random.default_rng(args.seed)
    ranks = np.minimum(rng.zipf(args.zipf_s, size=args.requests) - 1,
                       len(population) - 1)
    t0 = time.perf_counter()
    n_points = 0
    for i in range(0, len(ranks), args.batch):
        batch = [population[r] for r in ranks[i:i + args.batch]]
        results = svc.submit_batch(batch, data)
        n_points += sum(r.plan.n_points for r in results)
    dt = time.perf_counter() - t0

    s = svc.stats
    print(f"served {len(ranks)} requests / {n_points} points "
          f"in {dt:.2f}s ({len(ranks) / dt:.0f} req/s)")
    print(f"plan cache: {s.hits} hits / {s.misses} misses "
          f"(+{s.batch_dedup} batch-dedup) = {s.hit_rate:.0%} hit rate, "
          f"{s.evictions} evictions")
    print(f"planning {s.plan_time_s:.2f}s, shared gather "
          f"{s.gather_time_s:.2f}s, read sharing {s.sharing_factor:.2f}x")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "extract"], default="lm")
    ap.add_argument("--requests", type=int, default=8)
    # lm mode
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    # extract mode
    ap.add_argument("--grid-n", type=int, default=32)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--cache-capacity", type=int, default=256)
    ap.add_argument("--zipf-s", type=float, default=1.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mode == "extract":
        run_extract(args)
    else:
        from repro.configs import ARCH_IDS

        if args.arch not in ARCH_IDS:
            raise SystemExit(f"unknown arch {args.arch}")
        run_lm(args)


if __name__ == "__main__":
    main()
