"""Serving launcher: continuous-batching engine over the paged KV cache.

  python -m repro.launch.serve --arch glm4-9b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models.transformer import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a for a in ARCH_IDS],
                    default="glm4-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    # smoke config (full configs need a pod)
    import importlib

    from repro.configs import _MODULES

    mod = importlib.import_module(f"repro.configs.{_MODULES[args.arch]}")
    if not hasattr(mod, "_smoke"):
        raise SystemExit(f"{args.arch} has no LM smoke config")
    cfg = mod._smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, EngineConfig(
        max_batch=4, max_seq=128, page_size=16, n_pages=256))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24)
                                ).astype(np.int32),
            max_new_tokens=args.max_new_tokens))
    done = engine.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    print(f"KV pool utilization at end: {engine.pager.utilization:.0%}")


if __name__ == "__main__":
    main()
