"""Paged KV cache — the Polytope algorithm applied to serving.

The cache is a datacube over (page, kv_head, slot, head_dim); a decode
step needs exactly the pages of the live sequences.  The *planner* here
is the serving-side analogue of the paper's index tree: per sequence it
yields the page list (= extraction plan), and the attention kernel
(``repro.kernels.paged_attn``) scalar-prefetches that plan and DMAs only
those pages — never the dead ones (proved by the poisoning test in
``tests/test_kernels.py``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PagedKVCache:
    """Host-side page table manager (device arrays live in the engine)."""

    n_pages: int
    page_size: int
    max_pages_per_seq: int

    free_pages: list[int] = field(default_factory=list)
    tables: dict[int, list[int]] = field(default_factory=dict)
    lengths: dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        self.free_pages = list(range(self.n_pages))

    # -- allocation ------------------------------------------------------
    def allocate(self, seq_id: int, n_tokens: int) -> list[int]:
        need = (n_tokens + self.page_size - 1) // self.page_size
        if need > self.max_pages_per_seq:
            raise ValueError("sequence exceeds max pages")
        if need > len(self.free_pages):
            raise MemoryError("KV cache exhausted")
        pages = [self.free_pages.pop() for _ in range(need)]
        self.tables[seq_id] = pages
        self.lengths[seq_id] = n_tokens
        return pages

    def extend(self, seq_id: int) -> int | None:
        """Account one more token; allocate a page on boundary cross."""
        self.lengths[seq_id] += 1
        used = self.lengths[seq_id]
        have = len(self.tables[seq_id]) * self.page_size
        if used > have:
            if not self.free_pages:
                raise MemoryError("KV cache exhausted")
            page = self.free_pages.pop()
            self.tables[seq_id].append(page)
            return page
        return None

    def release(self, seq_id: int) -> None:
        self.free_pages.extend(self.tables.pop(seq_id))
        self.lengths.pop(seq_id)

    # -- extraction plan ---------------------------------------------------
    def plan(self, seq_ids: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Block table + lengths for a decode batch — the Polytope
        extraction plan over the KV datacube."""
        bt = np.full((len(seq_ids), self.max_pages_per_seq), -1,
                     np.int32)
        lens = np.zeros(len(seq_ids), np.int32)
        for i, sid in enumerate(seq_ids):
            pages = self.tables[sid]
            bt[i, :len(pages)] = pages
            lens[i] = self.lengths[sid]
        return bt, lens

    def slot(self, seq_id: int) -> tuple[int, int]:
        """(page, in-page slot) of the *next* token write."""
        pos = self.lengths[seq_id]
        return self.tables[seq_id][pos // self.page_size], \
            pos % self.page_size

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free_pages) / self.n_pages
