"""Serving engine: continuous batching over the paged KV cache.

Request lifecycle: queue → prefill (fills the sequence's pages) →
decode rounds (batched across live sequences, one token each) →
completion (pages released).  Admission is capacity-based: a request is
admitted when the page pool can hold its prompt + max_new_tokens —
deadlock-free by construction.

This engine drives the dense-cache ``decode_step`` for simplicity on
CPU tests; on TPU the attention inner loop is
``repro.kernels.paged_attn`` consuming the planner's block tables.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf

from .kv_cache import PagedKVCache


@dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 16
    rid: int = field(default_factory=itertools.count().__next__)
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 256
    page_size: int = 16
    n_pages: int = 512
    greedy: bool = True


class ServeEngine:
    def __init__(self, params: Any, cfg: tf.TransformerConfig,
                 ecfg: EngineConfig):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.pager = PagedKVCache(
            ecfg.n_pages, ecfg.page_size,
            max_pages_per_seq=ecfg.max_seq // ecfg.page_size)
        self.queue: list[Request] = []
        self.live: dict[int, dict] = {}      # rid → {cache, pos, req}
        self._decode = jax.jit(
            lambda p, c, t, pos: tf.decode_step(p, cfg, c, t, pos))

    # -- API -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> list[Request]:
        done: list[Request] = []
        while self.queue or self.live:
            self._admit()
            self._decode_round()
            done.extend(self._collect())
        return done

    # -- internals ---------------------------------------------------------
    def _admit(self) -> None:
        while self.queue and len(self.live) < self.ecfg.max_batch:
            req = self.queue[0]
            total = len(req.prompt) + req.max_new_tokens
            pages_needed = (total + self.ecfg.page_size - 1) \
                // self.ecfg.page_size
            if pages_needed > len(self.pager.free_pages):
                break                        # admission control
            self.queue.pop(0)
            self.pager.allocate(req.rid, len(req.prompt))
            prompt = jnp.asarray(req.prompt[None, :])
            logits, cache = tf.prefill(self.params, self.cfg, prompt,
                                       max_seq=self.ecfg.max_seq)
            nxt = int(jnp.argmax(logits[0]))
            req.out_tokens.append(nxt)
            self.pager.extend(req.rid)
            self.live[req.rid] = {"cache": cache, "req": req,
                                  "pos": len(req.prompt)}

    def _decode_round(self) -> None:
        if not self.live:
            return
        # continuous batching: one decode step per live sequence, each
        # against its own cache (batched per-sequence for CPU clarity;
        # the TPU path batches through the paged kernel)
        for rid, entry in list(self.live.items()):
            req = entry["req"]
            token = jnp.asarray([req.out_tokens[-1]], dtype=jnp.int32)
            pos = jnp.asarray([entry["pos"]], dtype=jnp.int32)
            logits, cache = self._decode(self.params, entry["cache"],
                                         token, pos)
            nxt = int(jnp.argmax(logits[0]))
            req.out_tokens.append(nxt)
            self.pager.extend(rid)
            entry["cache"] = cache
            entry["pos"] += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True

    def _collect(self) -> list[Request]:
        done = []
        for rid in [r for r, e in self.live.items()
                    if e["req"].done]:
            self.pager.release(rid)
            done.append(self.live.pop(rid)["req"])
        return done
