# The extraction service is the light half of this package (needs only
# repro.core); the LM engine pulls the full model stack, so it loads
# lazily — `repro.serve.engine` still works as an attribute and
# `from repro.serve.engine import ...` as a module path.
import importlib

from .extraction import (CacheStats, ExtractionService,  # noqa: F401
                         PlanCache, ServiceResult)

# sharded pulls distributed.sharding (jax) — lazy keeps the light half light
_LAZY = ("engine", "kv_cache", "sharded")


def __getattr__(name: str):
    if name in _LAZY:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
