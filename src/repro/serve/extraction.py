"""Extraction service: plan caching + batched request serving (DESIGN.md §4).

Production request streams against a datacube are highly repetitive —
the same country crop every forecast cycle, the same recsys region every
step, the same flight corridor for every flight on a route.  Re-running
Algorithm 1 per request makes *planning*, not I/O, the bottleneck at
scale.  This layer:

* keys every request by its canonical content hash
  (``Request.canonical_hash``) so permuted-but-equivalent requests
  collide;
* serves :class:`~repro.core.index_tree.ExtractionPlan` objects from a
  bounded LRU (:class:`PlanCache`) with hit/miss/eviction counters
  exposed like ``SliceStats``;
* dedupes concurrent requests inside a batch (plan once, share the
  plan object);
* executes all cache-missed gathers of a batch through one shared
  coalesced-run union read, so overlapping requests read each byte once.

Plans are immutable once built, so cache hits return the *same* plan
object — byte-identical offsets to the cold plan by construction.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, fields, replace
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core import PolytopeExtractor, Request, gather
from repro.core.datacube import Datacube
from repro.core.delta_planner import DeltaPlanner
from repro.core.index_tree import ExtractionPlan, coalesce_runs
from repro.core.shapes import CANON_TOL
from repro.core.slicer import SliceStats


@dataclass
class CacheStats:
    """Plan-cache instrumentation (the serving analogue of SliceStats)."""

    hits: int = 0                   # plan served from the LRU
    misses: int = 0                 # plan built by Algorithm 1
    evictions: int = 0              # plans dropped at capacity
    batch_dedup: int = 0            # duplicate requests inside one batch
    plan_time_s: float = 0.0        # cumulative cold-planning walltime
    gather_time_s: float = 0.0      # cumulative shared-gather walltime
    bytes_requested: int = 0        # sum over served requests
    bytes_read: int = 0             # union reads actually issued
    plans_shipped: int = 0          # cold plans shipped to peer replicas
    plans_received: int = 0         # peer plans installed locally
    migrations: int = 0             # entries popped for shard rebalance
    delta_hits: int = 0             # misses served by plan splicing
    delta_misses: int = 0           # misses with no splicable neighbor
    delta_time_s: float = 0.0       # cumulative splice walltime

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    @property
    def sharing_factor(self) -> float:
        """requested/read ≥ 1: how much the batch union read saved.

        Edge cases are explicit: nothing requested *and* nothing read
        (only empty plans in the batch) shares nothing and reports the
        neutral 1.0; bytes requested with **zero** bytes read is
        infinite sharing (``inf``), not 1.0 — returning 1.0 here would
        silently under/over-report savings on empty-gather batches
        (pinned by the regression test in tests/test_plan_cache.py).
        """
        if self.bytes_read:
            return self.bytes_requested / self.bytes_read
        return float("inf") if self.bytes_requested else 1.0


def merge_stats(parts: Iterable[CacheStats]) -> CacheStats:
    """Field-wise sum of :class:`CacheStats` (derived rates recompute
    from the summed counters) — shard aggregation for the sharded cache."""
    out = CacheStats()
    for s in parts:
        for f in fields(CacheStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
    return out


class PlanCache:
    """Bounded LRU of ``canonical_hash → ExtractionPlan``.

    Thread-safe: an internal lock serializes every OrderedDict access.
    ``keys()``/``__contains__`` racing a concurrent ``put`` eviction
    would otherwise iterate the dict mid-mutation — the unsynchronized
    read the lock-discipline fixture in ``tests/test_analysis.py`` pins
    as a regression.
    """

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._od: OrderedDict[str, ExtractionPlan] = OrderedDict()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._od)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._od

    def get(self, key: str) -> ExtractionPlan | None:
        with self._lock:
            plan = self._od.get(key)
            if plan is None:
                self.stats.misses += 1
                return None
            self._od.move_to_end(key)
            self.stats.hits += 1
            return plan

    def put(self, key: str, plan: ExtractionPlan) -> None:
        with self._lock:
            if key in self._od:
                self._od.move_to_end(key)
            self._od[key] = plan
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)
                self.stats.evictions += 1

    def peek(self, key: str) -> ExtractionPlan | None:
        """Uncounted, non-mutating lookup: the delta planner fetching a
        *parent* plan is not a request-path cache lookup, so it must not
        perturb the hit/miss counters (``lookups == hits + misses``
        stays tied to served requests) nor the LRU order (eviction
        reflects what users requested, not which parents were spliced
        from — the freshly spliced child is put at MRU anyway)."""
        with self._lock:
            return self._od.get(key)

    def pop(self, key: str) -> ExtractionPlan | None:
        """Remove and return ``key``'s plan (shard-rebalance migration).

        Counts ``stats.migrations`` when an entry was actually removed —
        without the counter, rebalance mutated the cache invisibly and
        the stats-conservation invariant in
        tests/test_serve_concurrent.py silently ignored migrated
        entries."""
        with self._lock:
            plan = self._od.pop(key, None)
            if plan is not None:
                self.stats.migrations += 1
            return plan

    def keys(self) -> list[str]:
        """LRU → MRU order (eviction order is the front)."""
        with self._lock:
            return list(self._od)

    def record(self, **deltas: float) -> None:
        """Atomically bump :class:`CacheStats` counters by name
        (``record(plan_time_s=dt, batch_dedup=1)``)."""
        with self._lock:
            for name, d in deltas.items():
                setattr(self.stats, name, getattr(self.stats, name) + d)

    def snapshot(self) -> CacheStats:
        """Consistent copy of the counters (safe to aggregate lock-free)."""
        with self._lock:
            return replace(self.stats)


@dataclass
class NeighborEntry:
    """One remembered request under a shape signature: where its plan
    lives (exact cache key), its per-axis anchor, and what it asked for
    (the delta planner re-slices changed leading slabs against it)."""

    key: str
    anchor: dict[str, float]
    request: Request
    stats: SliceStats


class NeighborhoodIndex:
    """Bounded two-level LRU: ``shape signature → recent requests``.

    The exact-match LRU misses every *drifted* repeat of a request; this
    index keys on the translation-invariant signature
    (``Request.shape_signature``) so a drifted request finds its parent
    plan, with the anchor delta left for the delta planner to apply.
    ``per_signature`` bounds the anchors remembered per shape; candidates
    come back MRU-first so the nearest parent is tried first.  The bound
    must absorb *interleaved* chains: congruent shapes at incompatible
    anchors (e.g. same-size boxes at different latitudes on the
    non-uniform Gaussian axis) share a signature, and a Zipf-skewed hot
    chain can flush a colder chain's parent out of too small a window.

    Thread-safe behind its own lock — entries are immutable once added.
    """

    def __init__(self, capacity: int = 1024, per_signature: int = 32):
        if capacity < 1 or per_signature < 1:
            raise ValueError("capacity and per_signature must be >= 1")
        self.capacity = capacity
        self.per_signature = per_signature
        self._od: OrderedDict[str, OrderedDict[str, NeighborEntry]] = \
            OrderedDict()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return sum(len(inner) for inner in self._od.values())

    def add(self, sig: str, key: str, anchor: dict[str, float],
            request: Request, stats: SliceStats) -> None:
        with self._lock:
            inner = self._od.get(sig)
            if inner is None:
                inner = OrderedDict()
                self._od[sig] = inner
            else:
                self._od.move_to_end(sig)
            if key in inner:
                inner.move_to_end(key)
            inner[key] = NeighborEntry(key=key, anchor=anchor,
                                       request=request, stats=stats)
            while len(inner) > self.per_signature:
                inner.popitem(last=False)
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)

    def candidates(self, sig: str) -> list[NeighborEntry]:
        """Entries under ``sig``, most-recently-added first."""
        with self._lock:
            inner = self._od.get(sig)
            if inner is None:
                return []
            self._od.move_to_end(sig)
            return list(reversed(inner.values()))

    # -- sharded-migration surface (repro.serve.sharded) -------------------
    def signatures(self) -> list[str]:
        with self._lock:
            return list(self._od)

    def pop_signature(self, sig: str
                      ) -> "OrderedDict[str, NeighborEntry] | None":
        with self._lock:
            return self._od.pop(sig, None)

    def install(self, sig: str,
                entries: "OrderedDict[str, NeighborEntry]") -> None:
        with self._lock:
            inner = self._od.setdefault(sig, OrderedDict())
            inner.update(entries)
            while len(inner) > self.per_signature:
                inner.popitem(last=False)
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)


@dataclass
class ServiceResult:
    """One served request: its plan, optional gathered values, and how
    the plan was obtained (``stats`` is None unless planned cold)."""

    request: Request
    key: str
    plan: ExtractionPlan
    cached: bool
    values: Any | None = None
    stats: SliceStats | None = None


class ExtractionService:
    """Many concurrent polytope requests → deduped, cached, batched
    extraction over one datacube.

    Thread-safe: the pipeline prefetcher calls :meth:`submit_batch` from
    its worker thread while launchers may probe stats from the main
    thread.
    """

    def __init__(self, datacube: Datacube, capacity: int = 1024,
                 use_kernel: bool = False, tol: float = CANON_TOL,
                 periods: dict[str, float] | None = None,
                 verify: bool = False, delta: bool = True,
                 drift_steps: int = 64):
        self.datacube = datacube
        # verify=True machine-checks every cold plan AND every shared
        # union plan against the invariants in repro.analysis.plan_check
        # (DESIGN.md §6) — the serving-layer switch for the paper's
        # byte-exactness contract.
        self.verify = verify
        self.extractor = PolytopeExtractor(datacube, use_kernel=use_kernel,
                                           verify=verify)
        self.cache = PlanCache(capacity)
        self.tol = tol
        # Cyclic-axis periods fold into the cache key: seam-straddling
        # requests shifted by whole periods hash identically, so the
        # plan cache hits across the seam (DESIGN.md §2.5).
        self.periods = dict(periods) if periods is not None \
            else datacube.axis_periods()
        # delta=True routes exact-cache misses through the neighborhood
        # index + delta planner (DESIGN.md §8) before falling back to a
        # cold Algorithm-1 run; ineligible drifts fall through
        # transparently, same opt-out contract as the device planner.
        self.delta_planner = None
        self.neighborhood = None
        if delta:
            self.delta_planner = DeltaPlanner(
                datacube, slicer=self.extractor.slicer,
                max_steps=drift_steps)
            self.neighborhood = NeighborhoodIndex(capacity)
        self._lock = threading.Lock()

    @property
    def stats(self) -> CacheStats:
        with self._lock:
            return self.cache.stats

    # -- single request ----------------------------------------------------
    def plan(self, request: Request) -> tuple[ExtractionPlan, bool, str]:
        """Plan one request through the cache.

        Returns ``(plan, cached, key)``; a hit returns the exact plan
        object built on the cold miss.
        """
        key = request.canonical_hash(self.tol, self.periods)
        with self._lock:
            plan = self.cache.get(key)
            if plan is not None:
                return plan, True, key
            plan, _ = self._plan_miss(request, key)
            return plan, False, key

    def _plan_miss(self, request: Request,
                   key: str) -> tuple[ExtractionPlan, SliceStats]:
        """Serve an exact-cache miss (caller holds ``self._lock``):
        try a delta splice from a drifted neighbor first, cold-plan
        otherwise; either way install the plan and index the request's
        signature for future drifts."""
        if self.delta_planner is not None:
            out = self._try_delta(request, key)
            if out is not None:
                return out
        t0 = time.perf_counter()
        plan, stats = self.extractor.plan(request)
        dt = time.perf_counter() - t0
        self.cache.stats.plan_time_s += dt  # unlocked-ok: caller holds _lock
        self.cache.put(key, plan)           # unlocked-ok: caller holds _lock
        if self.neighborhood is not None and stats is not None:
            sig, anchor = request.shape_signature(self.tol)
            self.neighborhood.add(sig, key, anchor, request, stats)
        return plan, stats

    def _try_delta(self, request: Request, key: str
                   ) -> "tuple[ExtractionPlan, SliceStats] | None":
        """Resolve the request's signature in the neighborhood index and
        splice from the nearest parent whose drift is eligible.  Spliced
        plans verify (when ``self.verify``), install under the exact
        key, and re-index — so a drift *chain* keeps splicing from its
        latest member instead of walking back to the origin."""
        t0 = time.perf_counter()
        sig, anchor = request.shape_signature(self.tol)
        for entry in self.neighborhood.candidates(sig):
            shifts = self.delta_planner.axis_shifts(entry.anchor, anchor)
            if shifts is None:
                continue
            parent = self.cache.peek(entry.key)  # unlocked-ok: caller holds _lock
            if parent is None:
                continue   # parent evicted under the index entry
            out = self.delta_planner.splice(request, entry.request,
                                            parent, entry.stats, shifts)
            if out is None:
                continue
            plan, stats = out
            if self.verify:
                from repro.analysis.plan_check import verify_plan

                verify_plan(plan, datacube=self.datacube, stats=stats)
            self.cache.put(key, plan)  # unlocked-ok: caller holds _lock
            self.neighborhood.add(sig, key, anchor, request, stats)
            dt = time.perf_counter() - t0
            self.cache.stats.delta_hits += 1  # unlocked-ok: caller holds _lock
            self.cache.stats.delta_time_s += dt  # unlocked-ok: caller holds _lock
            return plan, stats
        self.cache.stats.delta_misses += 1  # unlocked-ok: caller holds _lock
        return None

    def extract(self, request: Request,
                flat_data: Any | None = None) -> ServiceResult:
        return self.submit_batch([request], flat_data)[0]

    # -- batched serving -----------------------------------------------------
    def submit_batch(self, requests: Sequence[Request],
                     flat_data: Any | None = None) -> list[ServiceResult]:
        """Serve a batch of concurrent requests.

        Requests are deduped by canonical hash (one plan per distinct
        geometry), missed plans run Algorithm 1 once, and — when
        ``flat_data`` is given — all distinct plans are gathered through
        a single coalesced union read shared across the batch.
        """
        keys = [r.canonical_hash(self.tol, self.periods) for r in requests]
        results: list[ServiceResult] = []
        batch_plans: dict[str, ExtractionPlan] = {}

        with self._lock:
            for req, key in zip(requests, keys):
                if key in batch_plans:
                    # same geometry earlier in this batch — share it
                    self.cache.stats.batch_dedup += 1
                    results.append(ServiceResult(
                        request=req, key=key, plan=batch_plans[key],
                        cached=True))
                    continue
                plan = self.cache.get(key)
                stats = None
                cached = plan is not None
                if plan is None:
                    plan, stats = self._plan_miss(req, key)
                batch_plans[key] = plan
                results.append(ServiceResult(
                    request=req, key=key, plan=plan, cached=cached,
                    stats=stats))

        # Gather outside the lock: plans are immutable and the results
        # are local, so concurrent callers only contend on the (short)
        # planning section, not on the batch I/O.  This discipline is no
        # longer just prose: repro.analysis.concurrency statically
        # verifies that all _lock-protected state (the cache) is only
        # touched inside `with self._lock` blocks — _gather_batch's
        # stats updates re-enter the lock below.
        if flat_data is not None:
            self._gather_batch(results, batch_plans, flat_data)
        return results

    def _gather_batch(self, results: list[ServiceResult],
                      batch_plans: dict[str, ExtractionPlan],
                      flat_data: Any) -> None:
        """One union read for the whole batch, then slice each request's
        values out of the shared buffer (coalesced-run sharing)."""
        requested, read, dt = shared_union_gather(
            self.datacube, results, batch_plans, flat_data,
            use_kernel=self.extractor.use_kernel, verify=self.verify)
        with self._lock:
            self.cache.stats.bytes_requested += requested
            self.cache.stats.bytes_read += read
            self.cache.stats.gather_time_s += dt


def shared_union_gather(datacube: Datacube,
                        results: list[ServiceResult],
                        batch_plans: dict[str, ExtractionPlan],
                        flat_data: Any,
                        use_kernel: bool = False,
                        verify: bool = False) -> tuple[int, int, float]:
    """Execute one coalesced union read for ``batch_plans`` and slice each
    result's values out of the shared buffer.

    Fills ``res.values`` in place and returns
    ``(bytes_requested, bytes_read, gather_time_s)`` so the caller can
    fold the accounting into its own stats under its own lock.  Shared
    between :class:`ExtractionService` and the sharded service in
    :mod:`repro.serve.sharded` — both funnel a window's distinct plans
    through exactly one gather.
    """
    nonempty = {k: p for k, p in batch_plans.items() if p.n_points}
    if not nonempty:
        for res in results:
            res.values = np.empty(0, datacube.dtype)
        return 0, 0, 0.0
    t0 = time.perf_counter()
    union = np.unique(np.concatenate(
        [p.offsets for p in nonempty.values()]))
    starts, lengths = coalesce_runs(union)
    union_plan = ExtractionPlan(
        offsets=union, run_starts=starts, run_lengths=lengths,
        coords={}, itemsize=datacube.dtype.itemsize)
    if verify:
        from repro.analysis.plan_check import verify_plan

        verify_plan(union_plan, datacube=datacube)
    buf = gather(flat_data, union_plan, use_kernel=use_kernel)
    per_key: dict[str, Any] = {}
    for key, plan in nonempty.items():
        idx = np.searchsorted(union, plan.offsets)
        per_key[key] = buf[idx]
    requested = 0
    for res in results:
        if res.plan.n_points:
            res.values = per_key[res.key]
        else:
            res.values = np.empty(0, datacube.dtype)
        requested += res.plan.nbytes
    return requested, union_plan.nbytes, time.perf_counter() - t0
