"""Extraction service: plan caching + batched request serving (DESIGN.md §4).

Production request streams against a datacube are highly repetitive —
the same country crop every forecast cycle, the same recsys region every
step, the same flight corridor for every flight on a route.  Re-running
Algorithm 1 per request makes *planning*, not I/O, the bottleneck at
scale.  This layer:

* keys every request by its canonical content hash
  (``Request.canonical_hash``) so permuted-but-equivalent requests
  collide;
* serves :class:`~repro.core.index_tree.ExtractionPlan` objects from a
  bounded LRU (:class:`PlanCache`) with hit/miss/eviction counters
  exposed like ``SliceStats``;
* dedupes concurrent requests inside a batch (plan once, share the
  plan object);
* executes all cache-missed gathers of a batch through one shared
  coalesced-run union read, so overlapping requests read each byte once.

Plans are immutable once built, so cache hits return the *same* plan
object — byte-identical offsets to the cold plan by construction.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, fields, replace
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core import PolytopeExtractor, Request, gather
from repro.core.datacube import Datacube
from repro.core.index_tree import ExtractionPlan, coalesce_runs
from repro.core.shapes import CANON_TOL
from repro.core.slicer import SliceStats


@dataclass
class CacheStats:
    """Plan-cache instrumentation (the serving analogue of SliceStats)."""

    hits: int = 0                   # plan served from the LRU
    misses: int = 0                 # plan built by Algorithm 1
    evictions: int = 0              # plans dropped at capacity
    batch_dedup: int = 0            # duplicate requests inside one batch
    plan_time_s: float = 0.0        # cumulative cold-planning walltime
    gather_time_s: float = 0.0      # cumulative shared-gather walltime
    bytes_requested: int = 0        # sum over served requests
    bytes_read: int = 0             # union reads actually issued
    plans_shipped: int = 0          # cold plans shipped to peer replicas
    plans_received: int = 0         # peer plans installed locally

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    @property
    def sharing_factor(self) -> float:
        """requested/read ≥ 1: how much the batch union read saved."""
        return self.bytes_requested / self.bytes_read if self.bytes_read \
            else 1.0


def merge_stats(parts: Iterable[CacheStats]) -> CacheStats:
    """Field-wise sum of :class:`CacheStats` (derived rates recompute
    from the summed counters) — shard aggregation for the sharded cache."""
    out = CacheStats()
    for s in parts:
        for f in fields(CacheStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(s, f.name))
    return out


class PlanCache:
    """Bounded LRU of ``canonical_hash → ExtractionPlan``.

    Thread-safe: an internal lock serializes every OrderedDict access.
    ``keys()``/``__contains__`` racing a concurrent ``put`` eviction
    would otherwise iterate the dict mid-mutation — the unsynchronized
    read the lock-discipline fixture in ``tests/test_analysis.py`` pins
    as a regression.
    """

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._od: OrderedDict[str, ExtractionPlan] = OrderedDict()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._od)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._od

    def get(self, key: str) -> ExtractionPlan | None:
        with self._lock:
            plan = self._od.get(key)
            if plan is None:
                self.stats.misses += 1
                return None
            self._od.move_to_end(key)
            self.stats.hits += 1
            return plan

    def put(self, key: str, plan: ExtractionPlan) -> None:
        with self._lock:
            if key in self._od:
                self._od.move_to_end(key)
            self._od[key] = plan
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)
                self.stats.evictions += 1

    def pop(self, key: str) -> ExtractionPlan | None:
        """Remove and return ``key``'s plan (shard-rebalance migration)."""
        with self._lock:
            return self._od.pop(key, None)

    def keys(self) -> list[str]:
        """LRU → MRU order (eviction order is the front)."""
        with self._lock:
            return list(self._od)

    def record(self, **deltas: float) -> None:
        """Atomically bump :class:`CacheStats` counters by name
        (``record(plan_time_s=dt, batch_dedup=1)``)."""
        with self._lock:
            for name, d in deltas.items():
                setattr(self.stats, name, getattr(self.stats, name) + d)

    def snapshot(self) -> CacheStats:
        """Consistent copy of the counters (safe to aggregate lock-free)."""
        with self._lock:
            return replace(self.stats)


@dataclass
class ServiceResult:
    """One served request: its plan, optional gathered values, and how
    the plan was obtained (``stats`` is None unless planned cold)."""

    request: Request
    key: str
    plan: ExtractionPlan
    cached: bool
    values: Any | None = None
    stats: SliceStats | None = None


class ExtractionService:
    """Many concurrent polytope requests → deduped, cached, batched
    extraction over one datacube.

    Thread-safe: the pipeline prefetcher calls :meth:`submit_batch` from
    its worker thread while launchers may probe stats from the main
    thread.
    """

    def __init__(self, datacube: Datacube, capacity: int = 1024,
                 use_kernel: bool = False, tol: float = CANON_TOL,
                 periods: dict[str, float] | None = None,
                 verify: bool = False):
        self.datacube = datacube
        # verify=True machine-checks every cold plan AND every shared
        # union plan against the invariants in repro.analysis.plan_check
        # (DESIGN.md §6) — the serving-layer switch for the paper's
        # byte-exactness contract.
        self.verify = verify
        self.extractor = PolytopeExtractor(datacube, use_kernel=use_kernel,
                                           verify=verify)
        self.cache = PlanCache(capacity)
        self.tol = tol
        # Cyclic-axis periods fold into the cache key: seam-straddling
        # requests shifted by whole periods hash identically, so the
        # plan cache hits across the seam (DESIGN.md §2.5).
        self.periods = dict(periods) if periods is not None \
            else datacube.axis_periods()
        self._lock = threading.Lock()

    @property
    def stats(self) -> CacheStats:
        with self._lock:
            return self.cache.stats

    # -- single request ----------------------------------------------------
    def plan(self, request: Request) -> tuple[ExtractionPlan, bool, str]:
        """Plan one request through the cache.

        Returns ``(plan, cached, key)``; a hit returns the exact plan
        object built on the cold miss.
        """
        key = request.canonical_hash(self.tol, self.periods)
        with self._lock:
            plan = self.cache.get(key)
            if plan is not None:
                return plan, True, key
            t0 = time.perf_counter()
            plan, _ = self.extractor.plan(request)
            self.cache.stats.plan_time_s += time.perf_counter() - t0
            self.cache.put(key, plan)
            return plan, False, key

    def extract(self, request: Request,
                flat_data: Any | None = None) -> ServiceResult:
        return self.submit_batch([request], flat_data)[0]

    # -- batched serving -----------------------------------------------------
    def submit_batch(self, requests: Sequence[Request],
                     flat_data: Any | None = None) -> list[ServiceResult]:
        """Serve a batch of concurrent requests.

        Requests are deduped by canonical hash (one plan per distinct
        geometry), missed plans run Algorithm 1 once, and — when
        ``flat_data`` is given — all distinct plans are gathered through
        a single coalesced union read shared across the batch.
        """
        keys = [r.canonical_hash(self.tol, self.periods) for r in requests]
        results: list[ServiceResult] = []
        batch_plans: dict[str, ExtractionPlan] = {}

        with self._lock:
            for req, key in zip(requests, keys):
                if key in batch_plans:
                    # same geometry earlier in this batch — share it
                    self.cache.stats.batch_dedup += 1
                    results.append(ServiceResult(
                        request=req, key=key, plan=batch_plans[key],
                        cached=True))
                    continue
                plan = self.cache.get(key)
                stats = None
                cached = plan is not None
                if plan is None:
                    t0 = time.perf_counter()
                    plan, stats = self.extractor.plan(req)
                    self.cache.stats.plan_time_s += \
                        time.perf_counter() - t0
                    self.cache.put(key, plan)
                batch_plans[key] = plan
                results.append(ServiceResult(
                    request=req, key=key, plan=plan, cached=cached,
                    stats=stats))

        # Gather outside the lock: plans are immutable and the results
        # are local, so concurrent callers only contend on the (short)
        # planning section, not on the batch I/O.  This discipline is no
        # longer just prose: repro.analysis.concurrency statically
        # verifies that all _lock-protected state (the cache) is only
        # touched inside `with self._lock` blocks — _gather_batch's
        # stats updates re-enter the lock below.
        if flat_data is not None:
            self._gather_batch(results, batch_plans, flat_data)
        return results

    def _gather_batch(self, results: list[ServiceResult],
                      batch_plans: dict[str, ExtractionPlan],
                      flat_data: Any) -> None:
        """One union read for the whole batch, then slice each request's
        values out of the shared buffer (coalesced-run sharing)."""
        requested, read, dt = shared_union_gather(
            self.datacube, results, batch_plans, flat_data,
            use_kernel=self.extractor.use_kernel, verify=self.verify)
        with self._lock:
            self.cache.stats.bytes_requested += requested
            self.cache.stats.bytes_read += read
            self.cache.stats.gather_time_s += dt


def shared_union_gather(datacube: Datacube,
                        results: list[ServiceResult],
                        batch_plans: dict[str, ExtractionPlan],
                        flat_data: Any,
                        use_kernel: bool = False,
                        verify: bool = False) -> tuple[int, int, float]:
    """Execute one coalesced union read for ``batch_plans`` and slice each
    result's values out of the shared buffer.

    Fills ``res.values`` in place and returns
    ``(bytes_requested, bytes_read, gather_time_s)`` so the caller can
    fold the accounting into its own stats under its own lock.  Shared
    between :class:`ExtractionService` and the sharded service in
    :mod:`repro.serve.sharded` — both funnel a window's distinct plans
    through exactly one gather.
    """
    nonempty = {k: p for k, p in batch_plans.items() if p.n_points}
    if not nonempty:
        for res in results:
            res.values = np.empty(0, datacube.dtype)
        return 0, 0, 0.0
    t0 = time.perf_counter()
    union = np.unique(np.concatenate(
        [p.offsets for p in nonempty.values()]))
    starts, lengths = coalesce_runs(union)
    union_plan = ExtractionPlan(
        offsets=union, run_starts=starts, run_lengths=lengths,
        coords={}, itemsize=datacube.dtype.itemsize)
    if verify:
        from repro.analysis.plan_check import verify_plan

        verify_plan(union_plan, datacube=datacube)
    buf = gather(flat_data, union_plan, use_kernel=use_kernel)
    per_key: dict[str, Any] = {}
    for key, plan in nonempty.items():
        idx = np.searchsorted(union, plan.offsets)
        per_key[key] = buf[idx]
    requested = 0
    for res in results:
        if res.plan.n_points:
            res.values = per_key[res.key]
        else:
            res.values = np.empty(0, datacube.dtype)
        requested += res.plan.nbytes
    return requested, union_plan.nbytes, time.perf_counter() - t0
