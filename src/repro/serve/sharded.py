"""Sharded extraction service with async admission (DESIGN.md §7).

Scaling the plan cache past one lock means exploiting what PR 2 set up:
cache keys are *stable sha256 content hashes* and plans are *immutable*.
Three layers build on that:

* :class:`ShardedPlanCache` — N independent :class:`PlanCache` shards
  behind a consistent-hash ring (:class:`repro.distributed.sharding.
  HashRing`).  A key's 64-bit hex prefix routes it to one shard, so
  concurrent requests for different geometries contend on different
  locks; adding a shard remaps only ~1/N of the key space and migrates
  exactly those entries.
* :class:`ShardedExtractionService` — per-shard planning locks replace
  the single ``ExtractionService`` lock: a cold miss serializes only
  against cold misses *on the same shard*.  Gathers still run lock-free
  (plans are immutable) through the same shared union read as the
  single-lock service.  Replicas connected via :meth:`connect_peer`
  receive every cold plan over the pickled-plan wire format that
  ``repro.analysis.plan_check``'s CLI consumes, so one replica's
  planning work warms the whole fleet — verified on receipt.
* :class:`AdmissionQueue` — async admission in front of
  ``submit_batch``: callers get a ``Future`` immediately, a worker
  drains the arrival window (every ``window_s`` or at ``max_batch``)
  and serves the whole window as one batch — duplicate geometries
  coalesce into one plan lookup and one slice of one shared union read
  *across callers*, not just within a single caller's batch.

Concurrency is validated twice: statically by the lock-discipline
checker in ``repro.analysis`` (CI-gated) and dynamically by the
barrier-started thread swarms in ``tests/test_serve_concurrent.py``.
"""

from __future__ import annotations

import pickle
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, fields, replace
from typing import Any, Iterable, Sequence

from repro.core import PolytopeExtractor, Request
from repro.core.datacube import Datacube
from repro.core.delta_planner import DeltaPlanner
from repro.core.index_tree import ExtractionPlan
from repro.core.shapes import CANON_TOL
from repro.distributed.sharding import HashRing
from repro.serve.extraction import (CacheStats, NeighborhoodIndex,
                                    PlanCache, ServiceResult, merge_stats,
                                    shared_union_gather)


# ---------------------------------------------------------------------------
# Plan shipping wire format
# ---------------------------------------------------------------------------

def serialize_plan(key: str, plan: ExtractionPlan,
                   n_elements: int | None = None) -> bytes:
    """Pickle a plan in the envelope ``repro.analysis --plan`` consumes
    (``{"plan": ..., "n_elements": ...}``), plus the cache key so the
    receiving replica can install it without re-canonicalizing."""
    return pickle.dumps({"plan": plan, "n_elements": n_elements,
                         "key": key})


def deserialize_plan(blob: bytes, verify: bool = True,
                     ) -> tuple[str, ExtractionPlan]:
    """Inverse of :func:`serialize_plan`; with ``verify`` the plan is
    machine-checked against its invariants before it can warm a cache —
    a corrupt or truncated shipment raises instead of installing."""
    obj = pickle.loads(blob)
    key, plan = obj["key"], obj["plan"]
    if verify:
        from repro.analysis.plan_check import verify_plan

        verify_plan(plan, n_elements=obj.get("n_elements"))
    return key, plan


# ---------------------------------------------------------------------------
# Consistent-hash-sharded plan cache
# ---------------------------------------------------------------------------

class ShardedPlanCache:
    """N :class:`PlanCache` shards behind a :class:`HashRing`.

    Reads/writes route by canonical-hash prefix and synchronize only on
    the owning shard's internal lock.  Topology changes
    (:meth:`add_shard`, :meth:`remove_shard`) are admin-plane: they
    serialize on ``_admin_lock`` and swap ring state atomically, so
    routing never observes a half-built ring.  The shard map itself is
    only ever grown via ``dict.update`` (atomic under the GIL) *before*
    the ring can route to the new shard.
    """

    def __init__(self, shards: Iterable[str] | int = 4,
                 capacity_per_shard: int = 1024, replicas: int = 64):
        if isinstance(shards, int):
            shards = tuple(f"shard{i}" for i in range(shards))
        names = tuple(shards)
        if not names:
            raise ValueError("need at least one shard")
        self.capacity_per_shard = capacity_per_shard
        self._caches: dict[str, PlanCache] = {
            n: PlanCache(capacity_per_shard) for n in names}
        # Per-shard neighborhood indices, routed by *signature* hash —
        # drifted variants of one shape share a signature, so they all
        # route to the same shard's index regardless of which shards
        # their exact keys live on (parent plans fetch globally via
        # :meth:`peek`).
        self._hoods: dict[str, NeighborhoodIndex] = {
            n: NeighborhoodIndex(capacity_per_shard) for n in names}
        self.ring = HashRing(names, replicas=replicas)
        self._admin_lock = threading.Lock()

    # -- routing -----------------------------------------------------------
    @property
    def shard_names(self) -> tuple[str, ...]:
        return self.ring.nodes

    def entry_of(self, key: str) -> tuple[str, PlanCache]:
        """Route once: ``(owning shard name, its cache)``.  One route per
        operation, so a concurrent rebalance can't split an operation
        across two different owners."""
        shard = self.ring.route(key)
        return shard, self._caches[shard]

    # -- the PlanCache surface, sharded ------------------------------------
    def get(self, key: str) -> ExtractionPlan | None:
        return self.entry_of(key)[1].get(key)

    def peek(self, key: str) -> ExtractionPlan | None:
        """Uncounted cross-shard plan fetch (delta-planner parents)."""
        return self.entry_of(key)[1].peek(key)

    def hood_of(self, sig: str) -> NeighborhoodIndex:
        """Neighborhood index owning signature ``sig`` (consistent
        routing: every drifted variant of a shape resolves here)."""
        return self._hoods[self.ring.route(sig)]

    def put(self, key: str, plan: ExtractionPlan) -> None:
        self.entry_of(key)[1].put(key, plan)

    def __contains__(self, key: str) -> bool:
        return key in self.entry_of(key)[1]

    def __len__(self) -> int:
        return sum(len(c) for c in self._caches.values())

    def keys(self) -> list[str]:
        return [k for c in self._caches.values() for k in c.keys()]

    def shard_sizes(self) -> dict[str, int]:
        return {n: len(self._caches[n]) for n in self.ring.nodes}

    @property
    def stats(self) -> CacheStats:
        """Fleet-wide counters: field-wise sum of per-shard snapshots."""
        return merge_stats(c.snapshot() for c in self._caches.values())

    # -- topology ----------------------------------------------------------
    def add_shard(self, name: str) -> int:
        """Add a shard and migrate the ~1/N entries it now owns.

        Returns the number of migrated entries.  Entries planned
        concurrently with the migration may land on the old owner and be
        re-planned once on their new shard — plans are immutable and
        content-addressed, so a duplicate plan is benign.
        """
        with self._admin_lock:
            if name in self._caches:
                raise ValueError(f"shard {name!r} already exists")
            # publish the cache before the ring can route to it
            self._caches.update({name: PlanCache(self.capacity_per_shard)})
            self._hoods.update(
                {name: NeighborhoodIndex(self.capacity_per_shard)})
            self.ring.add_node(name)
            return self._migrate()

    def remove_shard(self, name: str) -> int:
        """Drain a shard: its entries migrate to their new owners.

        The drained shard's *counters* fold into a surviving shard
        before the cache object is dropped, so fleet-wide ``stats``
        conserve across topology changes (including the ``migrations``
        the drain itself just counted)."""
        with self._admin_lock:
            if name not in self._caches or len(self._caches) == 1:
                raise ValueError(f"cannot remove shard {name!r}")
            self.ring.remove_node(name)
            moved = self._migrate(drain=name)
            drained = self._caches.pop(name).snapshot()
            self._hoods.pop(name)
            survivor = self._caches[self.ring.nodes[0]]
            survivor.record(**{f.name: getattr(drained, f.name)
                               for f in fields(CacheStats)})
            return moved

    def _migrate(self, drain: str | None = None) -> int:
        """Move every entry whose ring owner changed (caller holds the
        admin mutex; per-entry moves use the shard caches' own locks).
        ``PlanCache.pop`` counts each move in the source shard's
        ``stats.migrations``; neighborhood entries reroute by signature
        alongside (uncounted — they index plans, they aren't plans)."""
        moved = 0
        for old_name in list(self._caches):
            cache = self._caches[old_name]
            for key in cache.keys():
                owner = self.ring.route(key)
                if owner == old_name and old_name != drain:
                    continue
                plan = cache.pop(key)
                if plan is not None:   # racing eviction — nothing to move
                    self._caches[owner].put(key, plan)
                    moved += 1
        for old_name in list(self._hoods):
            hood = self._hoods[old_name]
            for sig in hood.signatures():
                owner = self.ring.route(sig)
                if owner == old_name and old_name != drain:
                    continue
                entries = hood.pop_signature(sig)
                if entries:
                    self._hoods[owner].install(sig, entries)
        return moved


# ---------------------------------------------------------------------------
# Sharded service
# ---------------------------------------------------------------------------

class ShardedExtractionService:
    """``ExtractionService`` semantics with per-shard locking and
    cross-replica plan shipping.

    The single service lock is gone: plan lookups synchronize on the
    owning shard's cache lock, cold planning serializes on a per-shard
    planning lock (so concurrent misses of the *same* geometry plan
    once, while misses on different shards plan in parallel), and
    gather accounting takes a dedicated I/O lock.  Gathers themselves
    run lock-free — plans are immutable.
    """

    def __init__(self, datacube: Datacube, shards: Iterable[str] | int = 4,
                 capacity_per_shard: int = 1024, use_kernel: bool = False,
                 tol: float = CANON_TOL,
                 periods: dict[str, float] | None = None,
                 verify: bool = False, replicas: int = 64,
                 name: str = "replica0", delta: bool = True,
                 drift_steps: int = 64):
        self.datacube = datacube
        self.verify = verify
        self.name = name
        self.extractor = PolytopeExtractor(datacube, use_kernel=use_kernel,
                                           verify=verify)
        self.shards = ShardedPlanCache(shards, capacity_per_shard,
                                       replicas=replicas)
        # Same transparent-fallback contract as ExtractionService: an
        # exact-cache miss first tries a delta splice from the
        # signature-routed neighborhood before planning cold.
        self.delta_planner = None
        if delta:
            self.delta_planner = DeltaPlanner(
                datacube, slicer=self.extractor.slicer,
                max_steps=drift_steps)
        self.tol = tol
        self.periods = dict(periods) if periods is not None \
            else datacube.axis_periods()
        self._plan_locks: dict[str, threading.Lock] = {
            n: threading.Lock() for n in self.shards.shard_names}
        self._peers: list[ShardedExtractionService] = []
        self._io_lock = threading.Lock()
        self.io_stats = CacheStats()

    # -- stats -------------------------------------------------------------
    @property
    def stats(self) -> CacheStats:
        """Service-wide counters: shard snapshots + gather accounting."""
        with self._io_lock:
            io = replace(self.io_stats)
        return merge_stats([self.shards.stats, io])

    # -- planning ----------------------------------------------------------
    def plan(self, request: Request) -> tuple[ExtractionPlan, bool, str]:
        plan, cached, key, _ = self._plan_one(request)
        return plan, cached, key

    def _plan_one(self, request: Request,
                  key: str | None = None):
        if key is None:
            key = request.canonical_hash(self.tol, self.periods)
        shard, cache = self.shards.entry_of(key)
        # Uncounted membership probe first, so the counted lookup below
        # runs exactly once per request (a double-check get would score
        # every cold plan as two misses and skew the hit rate).
        if key in cache:
            plan = cache.get(key)
            if plan is not None:
                return plan, True, key, None
        lock = self._plan_locks.setdefault(shard, threading.Lock())
        with lock:
            plan = cache.get(key)   # counted; did a racing thread win?
            if plan is not None:
                return plan, True, key, None
            spliced = None
            if self.delta_planner is not None:
                spliced = self._try_delta(request, key, cache)
            if spliced is not None:
                plan, sstats = spliced
            else:
                t0 = time.perf_counter()
                plan, sstats = self.extractor.plan(request)
                cache.record(plan_time_s=time.perf_counter() - t0)
                cache.put(key, plan)
                self._index_neighbor(request, key, sstats)
        self._ship(key, plan)
        return plan, False, key, sstats

    def _try_delta(self, request: Request, key: str, cache: PlanCache):
        """Splice from a drifted neighbor (caller holds the shard's
        plan lock).  The signature routes to one shard's neighborhood;
        parent plans fetch cross-shard by their exact keys.  Returns
        ``(plan, stats)`` or ``None`` (→ plan cold)."""
        t0 = time.perf_counter()
        sig, anchor = request.shape_signature(self.tol)
        hood = self.shards.hood_of(sig)
        for entry in hood.candidates(sig):
            shifts = self.delta_planner.axis_shifts(entry.anchor, anchor)
            if shifts is None:
                continue
            parent = self.shards.peek(entry.key)
            if parent is None:
                continue   # parent evicted under the index entry
            out = self.delta_planner.splice(request, entry.request,
                                            parent, entry.stats, shifts)
            if out is None:
                continue
            plan, stats = out
            if self.verify:
                from repro.analysis.plan_check import verify_plan

                verify_plan(plan, datacube=self.datacube, stats=stats)
            cache.put(key, plan)
            hood.add(sig, key, anchor, request, stats)
            cache.record(delta_hits=1,
                         delta_time_s=time.perf_counter() - t0)
            return plan, stats
        cache.record(delta_misses=1)
        return None

    def _index_neighbor(self, request: Request, key: str,
                        stats) -> None:
        if self.delta_planner is None or stats is None:
            return
        sig, anchor = request.shape_signature(self.tol)
        self.shards.hood_of(sig).add(sig, key, anchor, request, stats)

    # -- batched serving ---------------------------------------------------
    def extract(self, request: Request,
                flat_data: Any | None = None) -> ServiceResult:
        return self.submit_batch([request], flat_data)[0]

    def submit_batch(self, requests: Sequence[Request],
                     flat_data: Any | None = None) -> list[ServiceResult]:
        """Batch semantics identical to ``ExtractionService.submit_batch``
        — dedupe by canonical hash, plan misses once, one shared union
        read — but with no global lock on the planning path."""
        results: list[ServiceResult] = []
        batch_plans: dict[str, ExtractionPlan] = {}
        for req in requests:
            key = req.canonical_hash(self.tol, self.periods)
            if key in batch_plans:
                self.shards.entry_of(key)[1].record(batch_dedup=1)
                results.append(ServiceResult(
                    request=req, key=key, plan=batch_plans[key],
                    cached=True))
                continue
            plan, cached, key, sstats = self._plan_one(req, key)
            batch_plans[key] = plan
            results.append(ServiceResult(
                request=req, key=key, plan=plan, cached=cached,
                stats=sstats))
        if flat_data is not None:
            requested, read, dt = shared_union_gather(
                self.datacube, results, batch_plans, flat_data,
                use_kernel=self.extractor.use_kernel, verify=self.verify)
            with self._io_lock:
                self.io_stats.bytes_requested += requested
                self.io_stats.bytes_read += read
                self.io_stats.gather_time_s += dt
        return results

    # -- topology ----------------------------------------------------------
    def add_shard(self, name: str) -> int:
        """Grow the ring; returns the number of migrated cache entries."""
        self._plan_locks.setdefault(name, threading.Lock())
        return self.shards.add_shard(name)

    # -- cross-replica plan shipping ---------------------------------------
    def connect_peer(self, peer: "ShardedExtractionService") -> None:
        """Subscribe ``peer`` to this replica's cold plans (one-way;
        call on both services for symmetric warming)."""
        if peer is self:
            raise ValueError("a replica cannot peer with itself")
        self._peers.append(peer)

    def _ship(self, key: str, plan: ExtractionPlan) -> None:
        if not self._peers:
            return
        blob = serialize_plan(key, plan,
                              n_elements=self.datacube.n_elements)
        shipped = 0
        for peer in tuple(self._peers):
            peer.receive_plan(blob)
            shipped += 1
        self.shards.entry_of(key)[1].record(plans_shipped=shipped)

    def receive_plan(self, blob: bytes) -> str:
        """Install a peer's shipped plan (verified when ``verify``);
        returns the installed cache key."""
        key, plan = deserialize_plan(blob, verify=self.verify)
        _, cache = self.shards.entry_of(key)
        cache.put(key, plan)
        cache.record(plans_received=1)
        return key


# ---------------------------------------------------------------------------
# Async admission
# ---------------------------------------------------------------------------

@dataclass
class AdmissionStats:
    """Arrival-window coalescing instrumentation."""

    submitted: int = 0      # requests accepted into the queue
    served: int = 0         # futures resolved
    windows: int = 0        # batches drained
    coalesced: int = 0      # duplicate geometries folded within windows
    window_max: int = 0     # largest window drained

    @property
    def coalescing_factor(self) -> float:
        """served / distinct-planned ≥ 1: cross-caller sharing per
        window (1.0 = no duplicate geometry ever coalesced)."""
        distinct = self.served - self.coalesced
        return self.served / distinct if distinct else 1.0


class AdmissionQueue:
    """Async admission in front of a service's ``submit_batch``.

    Callers :meth:`submit` a request and immediately get a ``Future``.
    A worker thread drains the pending window whenever ``window_s``
    elapses or ``max_batch`` requests accumulate, and serves the whole
    window as one batch — so identical geometries arriving from
    *different* callers within a window coalesce into one plan lookup
    and one slice of one shared union read.
    """

    def __init__(self, service: Any, flat_data: Any | None = None,
                 window_s: float = 0.002, max_batch: int = 64):
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.service = service
        self.flat_data = flat_data
        self.window_s = window_s
        self.max_batch = max_batch
        self.stats = AdmissionStats()
        self._pending: list[tuple[Request, Future]] = []
        self._closed = False
        self._lock = threading.Condition()
        self._worker = threading.Thread(target=self._run,
                                        name="admission-worker",
                                        daemon=True)
        self._worker.start()

    # -- caller side -------------------------------------------------------
    def submit(self, request: Request) -> "Future[ServiceResult]":
        """Enqueue; the future resolves with the window's
        :class:`ServiceResult` for this request."""
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("AdmissionQueue is closed")
            self._pending.append((request, fut))
            self._lock.notify_all()
        return fut

    def extract(self, request: Request,
                timeout: float | None = None) -> ServiceResult:
        """Synchronous convenience: submit and wait."""
        return self.submit(request).result(timeout)

    def snapshot(self) -> AdmissionStats:
        with self._lock:
            return replace(self.stats)

    # -- worker side -------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._lock.wait()
                if self._closed and not self._pending:
                    return
                # Window open: wait out the arrival window (or fill up),
                # then drain everything that accumulated.
                deadline = time.monotonic() + self.window_s
                while (len(self._pending) < self.max_batch
                       and not self._closed):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._lock.wait(remaining)
                window = self._pending
                self._pending = []
                self.stats.submitted += len(window)
                self.stats.windows += 1
                self.stats.window_max = max(self.stats.window_max,
                                            len(window))
            self._serve_window(window)

    def _serve_window(self,
                      window: list[tuple[Request, Future]]) -> None:
        """Serve one drained window as a single batch (no admission lock
        held: planning/gather contend only on the service's locks)."""
        requests = [req for req, _ in window]
        try:
            results = self.service.submit_batch(requests, self.flat_data)
        except BaseException as e:
            for _, fut in window:
                fut.set_exception(e)
            return
        distinct = len({r.key for r in results})
        with self._lock:
            self.stats.served += len(results)
            self.stats.coalesced += len(results) - distinct
        for (_, fut), res in zip(window, results):
            fut.set_result(res)

    # -- lifecycle ---------------------------------------------------------
    def close(self, timeout: float | None = 10.0) -> None:
        """Drain remaining requests, then stop the worker."""
        with self._lock:
            self._closed = True
            self._lock.notify_all()
        self._worker.join(timeout)

    def __enter__(self) -> "AdmissionQueue":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
