# Static verification layer (DESIGN.md §6): machine-checks the contracts
# the rest of the repo states in prose.  Three analyzers, all pure and
# dependency-light (numpy + ast only — importing this package never pulls
# jax), runnable as `python -m repro.analysis` and as a pytest tier:
#
# plan_check   — runtime/offline verifier over ExtractionPlan invariants
#                (bounds, sortedness, run tiling, §5.2 slice bound,
#                int32 addressability before kernels consume offsets)
# lint         — repo-specific AST rules (float64 discipline in the exact
#                host planner, no load-then-filter in the data plane, no
#                unguarded int32 casts on offset-carrying arrays)
# concurrency  — lock-discipline race detector (attributes written under
#                `with self._lock` must not be touched outside it)
from .bench_schema import check_bench_file
from .concurrency import check_lock_discipline, check_lock_source
from .diagnostics import Diagnostic
from .lint import lint_source, lint_tree
from .plan_check import PlanVerificationError, check_plan, verify_plan

__all__ = [
    "Diagnostic",
    "PlanVerificationError", "check_plan", "verify_plan",
    "lint_source", "lint_tree",
    "check_lock_discipline", "check_lock_source",
    "check_bench_file",
]
