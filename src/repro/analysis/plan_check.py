"""Plan verifier: machine-checks ``ExtractionPlan`` invariants.

The paper's contract is byte-exactness — Algorithm 1 pre-selects "the
precise bytes of data which the user needs".  A single out-of-bounds
offset, a run that no longer tiles the offset set, or an offset past
2³¹ (silently truncated the moment ``kernels/gather`` casts indices to
int32) breaks that contract invisibly: small-cube tests keep passing
while a production-scale cube reads the wrong bytes.  ``check_plan``
states the invariants as code:

* offsets are a 1-D integer array, in-bounds for the datacube;
* offsets are strictly ascending (sorted + deduped — ``flatten`` sorts
  by storage offset so runs are ascending burst reads);
* ``(run_start, run_length)`` coalesced runs exactly tile the offset
  set: expanding the runs reproduces ``offsets`` element-for-element;
* every offset fits in int32 **before** any kernel consumes it
  (``kernels/gather`` scalar-prefetch indices are int32);
* every coordinate column has one entry per extracted point;
* when ``SliceStats`` are supplied and the cube's axis sizes are
  derivable, the paper's §5.2 bound  N_slices ≤ Σ_i Π_{j≤i} n_j  holds.

Everything here is duck-typed over the plan/datacube attributes and
imports nothing from ``repro`` — the checker stays importable without
jax and free of circular imports, so ``Slicer``/``ExtractionService``
can call it lazily under ``verify=True``.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from .diagnostics import Diagnostic, render

I32_LIMIT = 2 ** 31


class PlanVerificationError(ValueError):
    """Raised by :func:`verify_plan` when a plan violates its contract."""

    def __init__(self, diagnostics: list[Diagnostic]):
        self.diagnostics = diagnostics
        super().__init__(
            f"{len(diagnostics)} plan invariant violation(s):\n"
            + render(diagnostics))


def _axis_sizes(datacube: Any) -> list[int] | None:
    """Axis lengths in natural order, or None when not derivable from a
    path-free lookup (e.g. the octahedral cube's row-dependent lon)."""
    names = getattr(datacube, "axis_names", None)
    if names is None:
        return None
    try:
        return [len(datacube.axis(n, {})) for n in names]
    except Exception:
        return None


def check_plan(plan: Any, *, datacube: Any = None,
               n_elements: int | None = None,
               stats: Any = None) -> list[Diagnostic]:
    """Pure function: plan (+ optional datacube/stats) → diagnostics."""
    diags: list[Diagnostic] = []
    offs = np.asarray(plan.offsets)
    starts = np.asarray(plan.run_starts)
    lengths = np.asarray(plan.run_lengths)

    if offs.ndim != 1 or offs.dtype.kind not in "iu":
        diags.append(Diagnostic(
            "plan-offsets-dtype",
            f"offsets must be a 1-D integer array, got shape {offs.shape} "
            f"dtype {offs.dtype}"))
        return diags  # nothing downstream is meaningful

    if n_elements is None and datacube is not None:
        n_elements = getattr(datacube, "n_elements", None)

    if len(offs):
        lo, hi = int(offs.min()), int(offs.max())
        if lo < 0:
            diags.append(Diagnostic(
                "plan-bounds", f"negative offset {lo}"))
        if n_elements is not None and hi >= n_elements:
            diags.append(Diagnostic(
                "plan-bounds",
                f"offset {hi} out of bounds for a datacube of "
                f"{n_elements} elements"))
        if hi >= I32_LIMIT:
            itemsize = int(getattr(plan, "itemsize", 8))
            size = (f"{n_elements} elements "
                    f"(~{n_elements * itemsize / 2**30:.1f} GiB)"
                    if n_elements is not None else "unknown size")
            diags.append(Diagnostic(
                "plan-i32",
                f"offset {hi} does not fit in int32 (limit {I32_LIMIT - 1}); "
                f"datacube has {size} — kernels/gather casts offsets to "
                f"int32, so this plan would silently read the wrong bytes"))
        d = np.diff(offs)
        if np.any(d < 0):
            diags.append(Diagnostic(
                "plan-sorted",
                "offsets are not sorted ascending (flatten emits plans in "
                "storage order so runs are ascending burst reads)"))
        elif np.any(d == 0):
            diags.append(Diagnostic(
                "plan-dedup", "offsets contain duplicates"))

    # -- runs must exactly tile the offset set -----------------------------
    if len(starts) != len(lengths):
        diags.append(Diagnostic(
            "plan-runs-tile",
            f"{len(starts)} run starts vs {len(lengths)} run lengths"))
    elif len(lengths) and int(lengths.min()) < 1:
        diags.append(Diagnostic(
            "plan-run-length",
            f"non-positive run length {int(lengths.min())}"))
    else:
        total = int(lengths.sum()) if len(lengths) else 0
        if total != len(offs):
            diags.append(Diagnostic(
                "plan-runs-tile",
                f"runs cover {total} elements but the plan has "
                f"{len(offs)} offsets"))
        else:
            rebuilt = np.repeat(starts, lengths) + _run_ramp(lengths)
            if not np.array_equal(rebuilt, offs):
                diags.append(Diagnostic(
                    "plan-runs-tile",
                    "expanding (run_start, run_length) runs does not "
                    "reproduce the offset set"))

    # -- coordinate columns ------------------------------------------------
    coords = getattr(plan, "coords", None) or {}
    for name, col in coords.items():
        if len(col) != len(offs):
            diags.append(Diagnostic(
                "plan-coords",
                f"coords[{name!r}] has {len(col)} entries for "
                f"{len(offs)} points"))

    # -- paper §5.2 slice-count bound --------------------------------------
    if stats is not None and datacube is not None:
        sizes = _axis_sizes(datacube)
        if sizes:
            bound, prod = 0, 1
            for n in sizes:
                prod *= n
                bound += prod
            if stats.n_slices > bound:
                diags.append(Diagnostic(
                    "plan-slice-bound",
                    f"{stats.n_slices} slices exceeds the §5.2 bound "
                    f"Σ_i Π_j≤i n_j = {bound} for axis sizes {sizes}"))
    return diags


def _run_ramp(lengths: np.ndarray) -> np.ndarray:
    """[0..l0-1, 0..l1-1, ...] — per-run arange for run expansion."""
    if not len(lengths):
        return np.empty(0, np.int64)
    ends = np.cumsum(lengths)
    ramp = np.arange(int(ends[-1]), dtype=np.int64)
    return ramp - np.repeat(ends - lengths, lengths)


def verify_plan(plan: Any, *, datacube: Any = None,
                n_elements: int | None = None, stats: Any = None) -> None:
    """Raise :class:`PlanVerificationError` unless the plan is clean."""
    diags = check_plan(plan, datacube=datacube, n_elements=n_elements,
                       stats=stats)
    if diags:
        raise PlanVerificationError(diags)


def check_plan_file(path: str,
                    n_elements: int | None = None) -> list[Diagnostic]:
    """CLI entry: verify a pickled plan.

    Accepts either a bare ``ExtractionPlan`` pickle or a dict with keys
    ``plan`` and (optionally) ``n_elements``.
    """
    import pickle

    try:
        with open(path, "rb") as fh:
            obj = pickle.load(fh)
    except Exception as e:
        return [Diagnostic("plan-file", f"cannot load plan: {e}",
                           file=path)]
    if isinstance(obj, dict):
        plan = obj.get("plan", obj)
        n_elements = obj.get("n_elements", n_elements)
    else:
        plan = obj
    diags = check_plan(plan, n_elements=n_elements)
    return [Diagnostic(d.rule, d.message, file=path, line=d.line)
            for d in diags]
