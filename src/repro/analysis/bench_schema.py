"""Bench-trajectory schema check.

``BENCH_*.json`` files carry the perf trajectory PR-over-PR; a file that
stops parsing or silently drops a column rots the trajectory without
failing anything.  This tiny checker pins the contract per bench family
(dispatched on the payload's ``bench`` tag): valid JSON, a ``bench``
tag, a non-empty ``rows`` list, and every row carrying the expected
keys with numeric columns — byte/point reductions for
``BENCH_extraction.json``, latency/hit-rate/coalescing for
``BENCH_serve.json``.
"""

from __future__ import annotations

import json
import numbers
from pathlib import Path

from .diagnostics import Diagnostic

# key → required type (None = any JSON value)
EXTRACTION_ROW_SCHEMA: dict[str, type | None] = {
    "example": str,
    "polytope_bytes": numbers.Number,
    "bbox_bytes": numbers.Number,
    "traditional_bytes": numbers.Number,
    "n_points": numbers.Number,
    "reduction_vs_traditional": numbers.Number,
    "reduction_vs_bbox": numbers.Number,
    "plan_time_s": numbers.Number,
}

# Zipfian closed-loop load against the sharded service (launch/serve.py
# --mode extract): tail latency, cache efficacy, and cross-caller
# admission coalescing are the trajectory columns.
SERVE_ROW_SCHEMA: dict[str, type | None] = {
    "scenario": str,
    "requests": numbers.Number,
    "threads": numbers.Number,
    "shards": numbers.Number,
    "window_ms": numbers.Number,
    "p50_ms": numbers.Number,
    "p99_ms": numbers.Number,
    "req_per_s": numbers.Number,
    "hit_rate": numbers.Number,
    "coalescing_factor": numbers.Number,
}

# Device-planning / burst-gather microbench (benchmarks/roofline.py
# kernels_table): cold host-planner latency vs the fused device
# pipeline, plus gather bandwidth against the HBM roofline and the
# compressed-plan encoding ratio.
KERNELS_ROW_SCHEMA: dict[str, type | None] = {
    "scenario": str,
    "n_points": numbers.Number,
    "n_runs": numbers.Number,
    "host_plan_us": numbers.Number,
    "device_plan_us": numbers.Number,
    "plan_speedup": numbers.Number,
    "gather_us": numbers.Number,
    "burst_gather_us": numbers.Number,
    "gather_gbps": numbers.Number,
    "roofline_frac": numbers.Number,
    "compress_ratio": numbers.Number,
}

# Drifting-workload delta-planning bench (benchmarks/bench_delta.py):
# a Zipfian request stream whose polytopes translate between arrivals.
# Columns compare cold re-planning against neighborhood splicing and
# report how often the drift window actually hit.
DELTA_ROW_SCHEMA: dict[str, type | None] = {
    "scenario": str,
    "requests": numbers.Number,
    "drift_steps": numbers.Number,
    "delta_hits": numbers.Number,
    "delta_hit_rate": numbers.Number,
    "cold_plan_ms": numbers.Number,
    "warm_plan_ms": numbers.Number,
    "speedup": numbers.Number,
}

ROW_SCHEMAS: dict[str, dict[str, type | None]] = {
    "extraction": EXTRACTION_ROW_SCHEMA,
    "serve": SERVE_ROW_SCHEMA,
    "kernels": KERNELS_ROW_SCHEMA,
    "delta": DELTA_ROW_SCHEMA,
}


def check_bench_file(path: str | Path,
                     row_schema: dict | None = None) -> list[Diagnostic]:
    path = Path(path)
    rel = path.name
    if not path.exists():
        return [Diagnostic("bench-schema", "file does not exist",
                           file=rel)]
    try:
        payload = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [Diagnostic("bench-schema", f"invalid JSON: {e}",
                           file=rel, line=e.lineno)]
    diags: list[Diagnostic] = []
    if not isinstance(payload, dict) or "bench" not in payload:
        diags.append(Diagnostic(
            "bench-schema", "top level must be an object with a 'bench' "
            "tag", file=rel))
        return diags
    schema = row_schema
    if schema is None:
        tag = payload["bench"]
        schema = ROW_SCHEMAS.get(tag) if isinstance(tag, str) else None
        if schema is None:
            diags.append(Diagnostic(
                "bench-schema",
                f"unknown bench tag {tag!r} (registered: "
                f"{sorted(ROW_SCHEMAS)})", file=rel))
            return diags
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        diags.append(Diagnostic(
            "bench-schema", "'rows' must be a non-empty list", file=rel))
        return diags
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            diags.append(Diagnostic(
                "bench-schema", f"rows[{i}] is not an object", file=rel))
            continue
        label = row.get("example") or row.get("scenario", "?")
        for key, typ in schema.items():
            if key not in row:
                diags.append(Diagnostic(
                    "bench-schema",
                    f"rows[{i}] ({label}) is missing key {key!r}",
                    file=rel))
            elif typ is not None and not isinstance(row[key], typ):
                diags.append(Diagnostic(
                    "bench-schema",
                    f"rows[{i}].{key} should be {typ.__name__}, got "
                    f"{type(row[key]).__name__}", file=rel))
    return diags
