"""Repo-specific AST lint (DESIGN.md §6).

Three rules, each encoding a contract the design doc states in prose:

* ``planner-float32``   — float64 discipline in the exact host planner
  (``core/geometry.py``, ``core/hull.py``, ``core/slicer.py``): geometry
  planning must be float64 — a vertex a hair inside/outside a plane
  changes which bytes are read — so any ``float32`` literal, dtype
  attribute or cast in those files is a bug.
* ``load-then-filter``  — the data plane (``dataplane/``) must express
  selection as polytope requests, never materialize-then-mask
  (DESIGN.md §2: "There is no 'load then filter' anywhere").  Fires on
  boolean-mask subscripts — ``x[x > t]`` directly, or ``x[mask]`` where
  ``mask`` was assigned from a comparison in the same function.
* ``unchecked-i32-cast`` — in the plan/offset-carrying layers
  (``core/``, ``serve/``, ``kernels/gather/``, ``kernels/paged_attn/``,
  ``kernels/segment/``, ``kernels/slice/``, ``kernels/plan/``) every
  ``.astype(int32)`` must go through
  ``repro.kernels.checked_cast_i32``, which validates host-side that
  offsets fit in int32 before any kernel truncates them.

Suppression: a line carrying ``# lint-ok: <rule>`` (or a bare
``# lint-ok``) is exempt — the pragma is greppable, the prose comment it
replaces was not.

The linter is pure ``ast`` + strings; ``lint_source`` makes every rule
testable against in-memory bad-snippet fixtures.
"""

from __future__ import annotations

import ast
from pathlib import Path

from .diagnostics import Diagnostic

# Files under src/repro the float64-discipline rule covers: the exact
# host planner (geometry, hull pruning, Algorithm-1 slicer).
PLANNER_FLOAT64_FILES = (
    "core/geometry.py", "core/hull.py", "core/slicer.py")

# Path prefixes (relative to src/repro) per rule.
LOAD_THEN_FILTER_PATHS = ("dataplane/",)
I32_CAST_PATHS = ("core/", "serve/", "kernels/gather/",
                  "kernels/paged_attn/", "kernels/segment/",
                  "kernels/slice/", "kernels/plan/")
# The one module allowed to spell the cast: the bounds-checked helper.
I32_CAST_ALLOWLIST = ("kernels/_casting.py",)

PRAGMA = "# lint-ok"


def _pragma_lines(source: str) -> dict[int, str]:
    """1-based line → pragma suffix for lines carrying ``# lint-ok``."""
    out: dict[int, str] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        if PRAGMA in line:
            out[i] = line.split(PRAGMA, 1)[1].lstrip(": ").strip()
    return out


def _suppressed(pragmas: dict[int, str], line: int, rule: str) -> bool:
    tag = pragmas.get(line)
    return tag is not None and (tag == "" or rule in tag)


def _is_float32(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "float32":
        return True
    if isinstance(node, ast.Name) and node.id == "float32":
        return True
    return isinstance(node, ast.Constant) and node.value == "float32"


def _is_int32_ref(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "int32":
        return True
    if isinstance(node, ast.Name) and node.id == "int32":
        return True
    return isinstance(node, ast.Constant) and node.value == "int32"


def _check_planner_float32(tree: ast.AST, rel: str,
                           pragmas: dict[int, str]) -> list[Diagnostic]:
    diags = []
    for node in ast.walk(tree):
        if not _is_float32(node):
            continue
        # Docstrings/comments mentioning float32 are fine; an exact
        # "float32" constant or attribute is a dtype reference.
        line = getattr(node, "lineno", None)
        if line is not None and _suppressed(pragmas, line, "planner-float32"):
            continue
        diags.append(Diagnostic(
            "planner-float32",
            "float32 reference in the exact host planner — geometry "
            "planning is float64 (a vertex a hair off a plane changes "
            "which bytes are read)", file=rel, line=line))
    return diags


class _MaskFilterVisitor(ast.NodeVisitor):
    """Flags boolean-mask subscripts, tracking per-function names that
    were assigned from comparisons (``mask = x > t`` … ``x[mask]``)."""

    def __init__(self, rel: str, pragmas: dict[int, str]):
        self.rel = rel
        self.pragmas = pragmas
        self.diags: list[Diagnostic] = []
        self._mask_names: list[set[str]] = [set()]

    def _visit_scope(self, node: ast.AST) -> None:
        self._mask_names.append(set())
        self.generic_visit(node)
        self._mask_names.pop()

    visit_FunctionDef = _visit_scope
    visit_AsyncFunctionDef = _visit_scope

    def visit_Assign(self, node: ast.Assign) -> None:
        if isinstance(node.value, (ast.Compare, ast.BoolOp)):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self._mask_names[-1].add(tgt.id)
        self.generic_visit(node)

    def _is_mask(self, idx: ast.AST) -> bool:
        if isinstance(idx, (ast.Compare, ast.BoolOp)):
            return True
        return (isinstance(idx, ast.Name)
                and any(idx.id in scope for scope in self._mask_names))

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if isinstance(node.ctx, ast.Load) and self._is_mask(node.slice):
            if not _suppressed(self.pragmas, node.lineno,
                               "load-then-filter"):
                self.diags.append(Diagnostic(
                    "load-then-filter",
                    "boolean-mask selection over a materialized array — "
                    "the data plane must express selection as a polytope "
                    "request (DESIGN.md §2), not load-then-filter",
                    file=self.rel, line=node.lineno))
        self.generic_visit(node)


def _check_load_then_filter(tree: ast.AST, rel: str,
                            pragmas: dict[int, str]) -> list[Diagnostic]:
    v = _MaskFilterVisitor(rel, pragmas)
    v.visit(tree)
    return v.diags


def _check_i32_cast(tree: ast.AST, rel: str,
                    pragmas: dict[int, str]) -> list[Diagnostic]:
    diags = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        is_cast = (isinstance(f, ast.Attribute) and f.attr == "astype"
                   and node.args and _is_int32_ref(node.args[0]))
        # direct constructor casts: np.int32(x) / jnp.int32(x)
        is_cast = is_cast or (_is_int32_ref(f) and bool(node.args))
        if not is_cast:
            continue
        if _suppressed(pragmas, node.lineno, "unchecked-i32-cast"):
            continue
        diags.append(Diagnostic(
            "unchecked-i32-cast",
            "int32 cast on an offset-carrying array outside "
            "repro.kernels.checked_cast_i32 — a >2³¹-element cube "
            "silently truncates offsets here; route the cast through "
            "the bounds-checked helper", file=rel, line=node.lineno))
    return diags


def lint_source(source: str, rel: str) -> list[Diagnostic]:
    """Lint one module given its source and path relative to src/repro."""
    rel = rel.replace("\\", "/")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Diagnostic("syntax", f"cannot parse: {e}", file=rel,
                           line=e.lineno)]
    pragmas = _pragma_lines(source)
    diags: list[Diagnostic] = []
    if rel in PLANNER_FLOAT64_FILES:
        diags += _check_planner_float32(tree, rel, pragmas)
    if rel.startswith(LOAD_THEN_FILTER_PATHS):
        diags += _check_load_then_filter(tree, rel, pragmas)
    if (rel.startswith(I32_CAST_PATHS)
            and rel not in I32_CAST_ALLOWLIST):
        diags += _check_i32_cast(tree, rel, pragmas)
    return diags


def lint_tree(root: str | Path) -> list[Diagnostic]:
    """Lint every module under ``root`` (the ``src/repro`` directory)."""
    root = Path(root)
    diags: list[Diagnostic] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        diags += lint_source(path.read_text(), rel)
    return diags
