"""CLI for the static verification layer (DESIGN.md §6).

    python -m repro.analysis --all            # the CI gate
    python -m repro.analysis --lint --locks   # source analyzers only
    python -m repro.analysis --plan p.pkl     # verify a pickled plan
    python -m repro.analysis --bench BENCH_extraction.json

Exits non-zero on any diagnostic.  ``--all`` runs the lint, the
lock-discipline checker, the bench schema check (when the file exists)
and a planner self-check: a handful of real plans built against small
cubes, each required to verify clean — so the gate exercises
``plan_check`` against live planner output, not just fixtures.

Source analyzers are pure ast/json and never import jax; only the
``--self-check`` path imports the planner.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .bench_schema import check_bench_file
from .concurrency import check_lock_discipline
from .diagnostics import Diagnostic, render
from .lint import lint_tree
from .plan_check import check_plan, check_plan_file


def _default_src_root() -> Path:
    # in-repo layout: .../src/repro/analysis/__main__.py → src/repro
    return Path(__file__).resolve().parents[1]


def self_check() -> list[Diagnostic]:
    """Verify live planner output on small cubes (imports repro.core)."""
    import numpy as np

    from repro.core import (Box, OrderedAxis, Polygon, PolytopeExtractor,
                            Request, Select, TensorDatacube)

    cube = TensorDatacube([
        OrderedAxis("t", np.arange(4.0)),
        OrderedAxis("x", np.arange(32.0)),
        OrderedAxis("y", np.arange(32.0)),
    ])
    tri = np.array([[4.0, 2.0], [28.0, 9.0], [15.0, 30.0]])
    requests = {
        "box": Request([Select("t", [1.0]),
                        Box(("x", "y"), [3.0, 4.0], [10.0, 21.0])]),
        "triangle": Request([Select("t", [0.0]), Polygon(("x", "y"), tri)]),
        "span_all": Request([Box(("t", "x"), [0.0, 0.0], [3.0, 31.0])]),
    }
    pe = PolytopeExtractor(cube)
    diags: list[Diagnostic] = []
    for name, req in requests.items():
        plan, stats = pe.plan(req)
        for d in check_plan(plan, datacube=cube, stats=stats):
            diags.append(Diagnostic(d.rule, f"[self-check {name}] "
                                    + d.message))
    return diags


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification layer: plan checker, AST lint, "
                    "lock-discipline race detector, bench schema check.")
    ap.add_argument("--all", action="store_true",
                    help="run lint + locks + bench + planner self-check "
                         "(the CI gate)")
    ap.add_argument("--lint", action="store_true", help="AST lint rules")
    ap.add_argument("--locks", action="store_true",
                    help="lock-discipline checker")
    ap.add_argument("--self-check", action="store_true",
                    help="verify live planner output on small cubes")
    ap.add_argument("--bench", nargs="*", metavar="JSON",
                    help="bench files to schema-check (default: "
                         "BENCH_extraction.json / BENCH_serve.json / "
                         "BENCH_kernels.json / BENCH_delta.json "
                         "when present)")
    ap.add_argument("--plan", nargs="*", metavar="PKL", default=[],
                    help="pickled ExtractionPlan files to verify")
    ap.add_argument("--n-elements", type=int, default=None,
                    help="datacube element count for --plan bounds checks")
    ap.add_argument("--root", type=Path, default=None,
                    help="source root to analyze (default: the installed "
                         "repro package directory)")
    args = ap.parse_args(argv)

    src_root = args.root if args.root is not None else _default_src_root()
    diags: list[Diagnostic] = []
    ran = False

    if args.all or args.lint:
        ran = True
        diags += lint_tree(src_root)
    if args.all or args.locks:
        ran = True
        diags += check_lock_discipline(src_root)
    bench_files = list(args.bench or [])
    if args.all and not bench_files:
        for name in ("BENCH_extraction.json", "BENCH_serve.json",
                     "BENCH_kernels.json", "BENCH_delta.json"):
            default_bench = Path.cwd() / name
            if default_bench.exists():
                bench_files.append(default_bench)
    for bf in bench_files or []:
        ran = True
        diags += check_bench_file(bf)
    for pf in args.plan:
        ran = True
        diags += check_plan_file(pf, n_elements=args.n_elements)
    if args.all or args.self_check:
        ran = True
        diags += self_check()

    if not ran:
        ap.print_help()
        return 2
    if diags:
        print(render(diags), file=sys.stderr)
        print(f"\n{len(diags)} diagnostic(s).", file=sys.stderr)
        return 1
    print("repro.analysis: all checks clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
