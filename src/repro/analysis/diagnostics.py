"""Shared diagnostic record for every analyzer in ``repro.analysis``.

One flat record type keeps the CLI, the CI gate, and the fixture tests
uniform: an analyzer returns ``list[Diagnostic]`` and an empty list means
the checked artifact honours its contract.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Diagnostic:
    """One violation found by a static analyzer.

    ``rule`` is a stable kebab-case identifier (tests key on it), ``file``
    and ``line`` locate source-level findings (both None for plan-level
    findings, which have no source location).
    """

    rule: str
    message: str
    file: str | None = None
    line: int | None = None
    severity: str = "error"

    def __str__(self) -> str:
        loc = ""
        if self.file is not None:
            loc = f"{self.file}:{self.line if self.line else '?'}: "
        return f"{loc}[{self.rule}] {self.message}"


def render(diagnostics: list[Diagnostic]) -> str:
    return "\n".join(str(d) for d in diagnostics)
