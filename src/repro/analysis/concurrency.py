"""Lock-discipline race detector (DESIGN.md §6).

A static checker for the threaded serving layer
(``serve/extraction.py``, ``dataplane/pipeline.py`` — and anything else
that grows locks as the async-admission work lands).  Per class it

1. finds the lock attributes — ``self.X`` used as a ``with`` context
   manager where ``X`` ends in ``lock``;
2. infers the *protected set*: the first attribute after ``self`` in
   every assignment target written inside a ``with self._lock:`` body
   (``self.cache.stats.hits += 1`` protects ``cache``; subscript stores
   count too — ``self._od[key] = plan`` protects ``_od``);
3. flags any access — read or write — to a protected attribute outside
   a lock body.

``__init__`` is exempt (construction happens-before publication), and a
line carrying ``# unlocked-ok: <reason>`` is exempt — the pragma turns
"gather outside the lock is fine because plans are immutable" from a
prose comment into an annotation the checker verifies is present.

Protection is inferred from *writes only*: method calls under the lock
(``self.extractor.plan(...)``) do not mark ``extractor`` protected,
otherwise every collaborator touched inside the critical section would
poison the whole class with false positives.  The checker is therefore
deliberately one-sided: it can miss a mutation hidden behind a method
call, but everything it flags is a genuine unguarded access to state the
class itself mutates under its lock.
"""

from __future__ import annotations

import ast
from pathlib import Path

from .diagnostics import Diagnostic

PRAGMA = "# unlocked-ok"


def _pragma_lines(source: str) -> set[int]:
    return {i for i, line in enumerate(source.splitlines(), start=1)
            if PRAGMA in line}


def _self_root(node: ast.AST) -> str | None:
    """For an attribute chain rooted at ``self``, the first attribute
    after ``self`` (``self.cache.stats.hits`` → ``cache``)."""
    while isinstance(node, ast.Attribute):
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            return node.attr
        node = node.value
    return None


def _is_lock_ctx(expr: ast.AST) -> str | None:
    """``with self.X:`` where X looks like a lock → X."""
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
            and expr.value.id == "self" and expr.attr.lower().endswith("lock"):
        return expr.attr
    # also accept self._lock.acquire()-style contexts via with self._lock:
    return None


class _ProtectedCollector(ast.NodeVisitor):
    """Pass 1: attributes written under a ``with self.<lock>`` body."""

    def __init__(self) -> None:
        self.locks: set[str] = set()
        self.protected: set[str] = set()
        self._depth = 0

    def visit_With(self, node: ast.With) -> None:
        is_lock = False
        for item in node.items:
            lock = _is_lock_ctx(item.context_expr)
            if lock is not None:
                self.locks.add(lock)
                is_lock = True
        if is_lock:
            self._depth += 1
            for stmt in node.body:
                self.visit(stmt)
            self._depth -= 1
        else:
            self.generic_visit(node)

    def _record_target(self, tgt: ast.AST) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self._record_target(elt)
            return
        while isinstance(tgt, (ast.Subscript, ast.Starred)):
            tgt = tgt.value
        root = _self_root(tgt)
        if root is not None:
            self.protected.add(root)

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._depth:
            for tgt in node.targets:
                self._record_target(tgt)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self._depth:
            self._record_target(node.target)
        self.generic_visit(node)


class _UnguardedFinder(ast.NodeVisitor):
    """Pass 2: accesses to protected attributes outside lock bodies."""

    def __init__(self, cls: str, rel: str, protected: set[str],
                 pragmas: set[int]):
        self.cls = cls
        self.rel = rel
        self.protected = protected
        self.pragmas = pragmas
        self.diags: list[Diagnostic] = []
        self._locked = 0
        self._seen: set[tuple[int, str]] = set()

    def visit_With(self, node: ast.With) -> None:
        if any(_is_lock_ctx(i.context_expr) for i in node.items):
            self._locked += 1
            for stmt in node.body:
                self.visit(stmt)
            self._locked -= 1
        else:
            self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        root = _self_root(node)
        if (root in self.protected and not self._locked
                and node.lineno not in self.pragmas
                and (node.lineno, root) not in self._seen):
            self._seen.add((node.lineno, root))
            self.diags.append(Diagnostic(
                "lock-discipline",
                f"{self.cls}.{root} is written under the lock but "
                f"accessed here without it — take the lock or annotate "
                f"the line with '# unlocked-ok: <reason>'",
                file=self.rel, line=node.lineno))
        self.generic_visit(node)


def check_lock_source(source: str, rel: str) -> list[Diagnostic]:
    """Check one module's lock discipline from source text."""
    rel = rel.replace("\\", "/")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Diagnostic("syntax", f"cannot parse: {e}", file=rel,
                           line=e.lineno)]
    pragmas = _pragma_lines(source)
    diags: list[Diagnostic] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        collector = _ProtectedCollector()
        for stmt in node.body:
            collector.visit(stmt)
        protected = collector.protected - collector.locks
        if not protected:
            continue
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name != "__init__":
                finder = _UnguardedFinder(node.name, rel, protected,
                                          pragmas)
                finder.visit(stmt)
                diags += finder.diags
    return diags


def check_lock_discipline(root: str | Path) -> list[Diagnostic]:
    """Check every module under ``root`` (the ``src/repro`` directory)."""
    root = Path(root)
    diags: list[Diagnostic] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        diags += check_lock_source(path.read_text(), rel)
    return diags
