"""Sharded prefetching pipeline.

Each host plans + reads only its own batch shard (the extraction plan
is per-host); a background thread keeps ``depth`` batches ahead so the
accelerator never waits on the planner.  Step-addressable sources make
fault-tolerant replay deterministic (``repro.train.fault``).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


class Prefetcher:
    def __init__(self, source: Callable[[int], Any], depth: int = 2,
                 start_step: int = 0, put_fn: Callable | None = None):
        self.source = source
        self.depth = depth
        self.put_fn = put_fn or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.put_fn(self.source(step))
            except Exception as e:  # surface errors on the main thread
                self._q.put(e)
                return
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def device_put_sharded(batch: Any, sharding) -> Any:
    """Place a host batch onto the mesh with the given sharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch, sharding)
