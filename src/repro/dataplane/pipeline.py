"""Sharded prefetching pipeline.

Each host plans + reads only its own batch shard (the extraction plan
is per-host); a background thread keeps ``depth`` batches ahead so the
accelerator never waits on the planner.  Step-addressable sources make
fault-tolerant replay deterministic (``repro.train.fault``).

:class:`CachedExtractionSource` routes a step's polytope requests
through a shared :class:`~repro.serve.extraction.ExtractionService`, so
recurring request geometry across steps is served from the plan cache
instead of re-running Algorithm 1 (DESIGN.md §4).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


class Prefetcher:
    def __init__(self, source: Callable[[int], Any], depth: int = 2,
                 start_step: int = 0, put_fn: Callable | None = None):
        self.source = source
        self.depth = depth
        self.put_fn = put_fn or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.put_fn(self.source(step))
            except Exception as e:  # surface errors on the main thread
                self._q.put(e)
                return
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class CachedExtractionSource:
    """Step-addressable batch source planned through a shared service.

    ``request_fn(step)`` returns the step's polytope request(s); the
    whole list is submitted as ONE service batch, so duplicate geometry
    inside a step is planned once and overlapping reads coalesce, while
    geometry repeated *across* steps (the common production pattern —
    same crops every cycle) hits the LRU plan cache.  Designed to be the
    ``source`` of a :class:`Prefetcher`: the service is thread-safe, so
    planning happens on the prefetch thread while the accelerator runs.
    """

    def __init__(self, service, request_fn: Callable[[int], Any],
                 flat_data: Any | None = None,
                 collate: Callable[[int, list], Any] | None = None):
        self.service = service
        self.request_fn = request_fn
        self.flat_data = flat_data
        self.collate = collate

    def __call__(self, step: int) -> Any:
        reqs = self.request_fn(step)
        single = not isinstance(reqs, (list, tuple))
        batch = [reqs] if single else list(reqs)
        results = self.service.submit_batch(batch, self.flat_data)
        if self.collate is not None:
            return self.collate(step, results)
        return results[0] if single else results


def device_put_sharded(batch: Any, sharding) -> Any:
    """Place a host batch onto the mesh with the given sharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch, sharding)
