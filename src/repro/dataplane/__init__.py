# Data plane: every data-access path is a Polytope extraction — plan the
# exact indices first, then move only those bytes (DESIGN.md §2).
from . import graph, pipeline, recsys, tokens, weather  # noqa: F401
