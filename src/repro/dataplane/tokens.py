"""LM token pipeline over a document datacube.

The corpus is a 2-D datacube (document × position); a training batch is
a Polytope extraction: a box request over a document range × position
window, planned by the slicer and gathered with the exact-byte path.
Sharded loading: each data-parallel host plans and reads only its batch
rows (plan-first ethos end-to-end).  All rows of a batch are submitted
as one :class:`~repro.serve.extraction.ExtractionService` batch, so
duplicate windows plan once and recurring windows across steps/epochs
hit the plan cache (DESIGN.md §4).

Tokens are synthetic but *learnable*: a fixed-seed order-2 Markov chain,
so small LMs show decreasing loss in the examples/tests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import Box, OrderedAxis, Request, TensorDatacube


@dataclass
class TokenCube:
    vocab: int = 256
    n_docs: int = 1024
    doc_len: int = 2048
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # order-1 Markov transition with strong structure
        perm = rng.permutation(self.vocab)
        self._next = perm
        self._noise = rng
        doc_axis = OrderedAxis("doc", np.arange(self.n_docs, dtype=float))
        pos_axis = OrderedAxis("pos", np.arange(self.doc_len,
                                                dtype=float))
        self.cube = TensorDatacube([doc_axis, pos_axis],
                                   dtype=np.dtype(np.int32))
        from repro.serve.extraction import ExtractionService

        # Random windows mostly miss the cache in normal training; the
        # cache pays off on exact-step replay (fault-tolerant restore)
        # and epoch revisits, so keep it small — plans are per-row and
        # cheap to rebuild.
        self.service = ExtractionService(self.cube, capacity=512)

    def _doc(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100_003 + doc_id)
        toks = np.empty(self.doc_len, np.int32)
        toks[0] = rng.integers(self.vocab)
        flip = rng.random(self.doc_len) < 0.1
        rand = rng.integers(0, self.vocab, self.doc_len)
        for i in range(1, self.doc_len):
            toks[i] = rand[i] if flip[i] else self._next[toks[i - 1]]
        return toks

    def materialize(self) -> np.ndarray:
        """Flat datacube payload (lazy docs for big cubes)."""
        if not hasattr(self, "_flat"):
            self._flat = np.concatenate(
                [self._doc(d) for d in range(self.n_docs)])
        return self._flat

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, n_shards: int = 1) -> dict:
        """Step-addressable batch (deterministic replay for FT restore).

        The batch IS a polytope request: box over (doc range × window).
        """
        flat = self.materialize()
        rng = np.random.default_rng(step * 7919 + shard)
        rows = batch_size // n_shards
        docs = rng.integers(0, self.n_docs, rows)
        starts = rng.integers(0, self.doc_len - seq_len - 1, rows)
        if rows == 0:
            toks = np.empty((0, seq_len + 1), np.int32)
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        reqs = [Request([Box(("doc", "pos"), [d, s0], [d, s0 + seq_len])])
                for d, s0 in zip(docs, starts)]
        results = self.service.submit_batch(reqs, flat)
        toks = np.stack([res.values for res in results]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
