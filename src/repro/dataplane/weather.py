"""Weather datacube + domain-specific interface (paper §4.2 Meteorology).

Builds the O-grid cube the paper's Table 1 measures against (O1280 ⇒
6 599 680-point fields = "50.4 MB" at float64), synthesises smooth
physical fields, and exposes the domain-level requests: country
extraction, time-series, vertical profiles, flight paths.

:class:`IrregularWeatherCube` is the *Beyond Standard Datacubes*
scenario: merged date/time, mapped Gaussian latitudes, and a cyclic
longitude crossed by the UK polygon — a transformed view over regular
storage, with a :meth:`~IrregularWeatherCube.materialized` oracle for
the differential test harness.

Country boundaries are coarse public-domain polygon approximations —
byte counts depend only on area/geometry, which these preserve.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import (Box, CyclicTransform, Disk, MappedTransform,
                        MergedTransform, OctahedralGridDatacube, OrderedAxis,
                        Path, Point, Polygon, Request, Select, Span,
                        TensorDatacube, TransformedDatacube)

# (lat, lon) vertex rings — coarse but area-faithful country outlines
COUNTRIES: dict[str, np.ndarray] = {
    "germany": np.array([
        [54.8, 8.6], [54.4, 13.0], [53.5, 14.2], [51.1, 14.9],
        [50.3, 12.2], [48.8, 13.8], [47.5, 13.0], [47.6, 9.6],
        [48.6, 8.0], [49.4, 6.4], [51.0, 6.0], [51.8, 6.1],
        [53.2, 7.2], [53.9, 8.6]], dtype=np.float64),
    "france": np.array([
        [51.0, 2.5], [50.1, 1.6], [49.4, -0.2], [48.6, -1.4],
        [48.6, -4.6], [47.3, -2.5], [46.0, -1.1], [43.4, -1.8],
        [42.7, 3.0], [43.3, 6.6], [44.0, 7.6], [45.9, 6.8],
        [46.4, 6.1], [47.6, 7.6], [49.0, 8.2], [49.8, 4.9]],
        dtype=np.float64),
    "norway": np.array([
        [58.0, 7.0], [58.9, 5.5], [61.0, 4.9], [62.5, 6.0],
        [64.5, 10.5], [67.3, 14.0], [69.5, 18.0], [71.0, 25.8],
        [70.1, 30.8], [69.0, 29.0], [68.4, 22.0], [65.0, 13.5],
        [63.0, 11.5], [60.0, 12.5], [59.0, 11.0]], dtype=np.float64),
    # The UK outline straddles the 0°/360° longitude seam (lon −6.6…1.7):
    # the cross-seam scenario for cyclic-axis extraction (DESIGN.md §2.5).
    "uk": np.array([
        [58.6, -5.0], [57.6, -1.9], [54.6, -0.5], [52.9, 1.7],
        [51.1, 1.4], [50.1, -5.7], [51.6, -4.9], [53.4, -4.6],
        [54.4, -3.2], [55.5, -5.8], [57.0, -6.6]], dtype=np.float64),
    "italy": np.array([
        [46.6, 10.4], [46.4, 13.7], [44.8, 12.4], [43.5, 14.0],
        [41.9, 16.1], [40.0, 18.5], [39.8, 16.6], [38.0, 16.1],
        [38.3, 15.7], [40.0, 15.4], [41.2, 13.0],
        [42.4, 11.0], [43.8, 10.1], [44.4, 8.8], [43.8, 7.5],
        [45.1, 7.1], [45.9, 8.9]], dtype=np.float64),
}


@dataclass
class WeatherCube:
    """time × level × (lat → lon) octahedral datacube."""

    n: int = 32                 # O<n>; Table 1 uses 1280
    n_times: int = 8
    n_levels: int = 20
    dtype: np.dtype = np.dtype(np.float64)

    def __post_init__(self):
        self.time_axis = OrderedAxis("time",
                                     np.arange(self.n_times,
                                               dtype=np.float64) * 3600.0)
        self.level_axis = OrderedAxis("level",
                                      np.arange(self.n_levels,
                                                dtype=np.float64))
        self.cube = OctahedralGridDatacube(
            [self.time_axis, self.level_axis], n=self.n, dtype=self.dtype)

    # -- synthetic physical payload ----------------------------------------
    def field_data(self, seed: int = 0) -> np.ndarray:
        """Smooth (time, level, point) field — low-order harmonics."""
        rng = np.random.default_rng(seed)
        lat_rows = np.repeat(self.cube.latitudes, self.cube.row_counts)
        lon = np.concatenate([
            360.0 * np.arange(c) / c for c in self.cube.row_counts])
        lat_r, lon_r = np.radians(lat_rows), np.radians(lon)
        base = (15.0 * np.cos(lat_r) + 5.0 * np.sin(2 * lon_r) *
                np.cos(lat_r))
        out = np.empty((self.n_times, self.n_levels,
                        self.cube.points_per_field), self.dtype)
        for t in range(self.n_times):
            for l in range(self.n_levels):
                out[t, l] = (base + 0.5 * l + 0.1 * t
                             + rng.normal(0, 0.05))
        return out.reshape(-1)

    # -- domain-specific interface (paper Fig. 5 top level) -------------------
    def country_request(self, name: str, time: float = 0.0,
                        level: float = 0.0) -> Request:
        return Request([Select("time", [time]), Select("level", [level]),
                        Polygon(("lat", "lon"), COUNTRIES[name])])

    def country_box_request(self, name: str, time: float = 0.0,
                            level: float = 0.0) -> Request:
        poly = COUNTRIES[name]
        return Request([Select("time", [time]), Select("level", [level]),
                        Box(("lat", "lon"), poly.min(0), poly.max(0))])

    def timeseries_request(self, lat: float, lon: float,
                           t0: float, t1: float,
                           level: float = 0.0) -> Request:
        # Select on ordered axes snaps to the nearest grid point — the
        # paper's time-series use case ("extract data over particular
        # cities or specific points in space").
        return Request([Span("time", t0, t1), Select("level", [level]),
                        Select("lat", [lat]), Select("lon", [lon])])

    def profile_request(self, lat: float, lon: float,
                        time: float = 0.0) -> Request:
        return Request([Select("time", [time]),
                        Span("level", 0.0, self.n_levels - 1.0),
                        Select("lat", [lat]), Select("lon", [lon])])

    def flight_path_request(self, waypoints: np.ndarray,
                            width: float = 1.0) -> Request:
        """waypoints (K, 4): (time, level, lat, lon) — a swept tube."""
        base = Box(("level", "lat", "lon"),
                   [-0.5, -width / 2, -width / 2],
                   [0.5, width / 2, width / 2])
        return Request([
            Path(("time", "level", "lat", "lon"), base, waypoints)])


def gaussian_latitudes(n: int) -> np.ndarray:
    """``n`` Gaussian-quadrature latitudes, north→south (degrees).

    Legendre nodes cluster toward the poles — genuinely irregular
    spacing, the reduced-grid latitude ladder of production NWP output.
    """
    nodes, _ = np.polynomial.legendre.leggauss(n)
    return np.degrees(np.arcsin(nodes))[::-1].copy()


@dataclass
class IrregularWeatherCube:
    """Production-shaped irregular datacube (*Beyond Standard Datacubes*):

    * **merged** date + time-of-day axes presented as one ``datetime``
      logical axis (seconds);
    * **mapped** Gaussian latitudes — storage holds plain row indices,
      the logical ``lat`` axis carries the irregularly spaced physical
      coordinates;
    * **cyclic** ``lon`` with period 360° — requests (e.g. the UK
      polygon) may straddle the 0°/360° seam.

    Storage is a regular ``TensorDatacube``; all irregularity lives in
    the transform layer, so :meth:`materialized` can build the
    explicitly unrolled/merged/remapped equivalent cube with the *same*
    flat layout — the oracle for the differential test harness
    (tests/test_transforms.py).
    """

    n_dates: int = 2
    times_per_day: int = 4
    n_levels: int = 3
    n_lat: int = 96
    n_lon: int = 192
    dtype: np.dtype = np.dtype(np.float64)

    def __post_init__(self):
        self.date_values = np.arange(self.n_dates) * 86400.0
        self.time_values = np.arange(self.times_per_day) * (
            86400.0 / self.times_per_day)
        self.latitudes = gaussian_latitudes(self.n_lat)
        self.lon_values = 360.0 * np.arange(self.n_lon) / self.n_lon
        base = TensorDatacube([
            OrderedAxis("date", self.date_values),
            OrderedAxis("time", self.time_values),
            OrderedAxis("level", np.arange(float(self.n_levels))),
            OrderedAxis("lat_row", np.arange(float(self.n_lat))),
            OrderedAxis("lon", self.lon_values),
        ], dtype=self.dtype)
        self.transforms = [
            MergedTransform("datetime", ("date", "time")),
            MappedTransform("lat", "lat_row", values=self.latitudes),
            CyclicTransform("lon", period=360.0),
        ]
        self.cube = TransformedDatacube(base, self.transforms)

    @property
    def datetime_values(self) -> np.ndarray:
        return (self.date_values[:, None] +
                self.time_values[None, :]).ravel()

    def materialized(self) -> TensorDatacube:
        """The explicitly merged/remapped cube over plain axes — same
        flat storage layout, so plans against it are the byte-exact
        reference for transformed extraction (cross-seam requests must
        be split manually; see tests/test_transforms.py)."""
        return TensorDatacube([
            OrderedAxis("datetime", self.datetime_values),
            OrderedAxis("level", np.arange(float(self.n_levels))),
            OrderedAxis("lat", self.latitudes),
            OrderedAxis("lon", self.lon_values),
        ], dtype=self.dtype)

    # -- synthetic physical payload ----------------------------------------
    def field_data(self, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        lat_r = np.radians(self.latitudes)
        lon_r = np.radians(self.lon_values)
        grid = (15.0 * np.cos(lat_r)[:, None] +
                5.0 * np.sin(2 * lon_r)[None, :] * np.cos(lat_r)[:, None])
        n_dt = self.n_dates * self.times_per_day
        out = np.empty((n_dt, self.n_levels, self.n_lat, self.n_lon),
                       self.dtype)
        for t in range(n_dt):
            for l in range(self.n_levels):
                out[t, l] = grid + 0.5 * l + 1e-4 * t + rng.normal(0, 0.05)
        return out.reshape(-1)

    # -- domain-specific interface -----------------------------------------
    def country_request(self, name: str, datetime: float = 0.0,
                        level: float = 0.0) -> Request:
        """Country crop; ``uk`` straddles the longitude seam."""
        return Request([Select("datetime", [datetime]),
                        Select("level", [level]),
                        Polygon(("lat", "lon"), COUNTRIES[name])])

    def timeseries_request(self, lat: float, lon: float, t0: float,
                           t1: float, level: float = 0.0) -> Request:
        """Point time-series; a [t0, t1] spanning a date boundary crosses
        the merged date/time storage split transparently."""
        return Request([Span("datetime", t0, t1), Select("level", [level]),
                        Select("lat", [lat]), Select("lon", [lon])])

    def seam_box_request(self, lat_lo: float, lat_hi: float,
                         lon_lo: float, lon_hi: float,
                         datetime: float = 0.0,
                         level: float = 0.0) -> Request:
        """Axis-aligned crop in unwrapped lon coordinates (may straddle
        the seam, e.g. lon −20…20)."""
        return Request([Select("datetime", [datetime]),
                        Select("level", [level]),
                        Box(("lat", "lon"), [lat_lo, lon_lo],
                            [lat_hi, lon_hi])])


# Default spot locations for serving mixes: London, Paris, New York,
# Tokyo (lat, lon).
SPOT_LOCATIONS = ((51.5, 0.0), (48.9, 2.3), (40.7, -74.0), (35.7, 139.7))


def request_population(wc: WeatherCube,
                       spots=SPOT_LOCATIONS) -> list[Request]:
    """Ranked serving-mix population: country crops × time/level, spot
    time-series, vertical profiles.  Zipf-sampling over this list makes
    a few crops hot — the repetitive production stream the plan cache
    (DESIGN.md §4) targets; used by ``launch/serve.py --mode extract``
    and ``benchmarks/bench_plan_cache.py``."""
    population = []
    for name in COUNTRIES:
        for t in (0.0, 3600.0):
            for lev in (0.0, 1.0):
                population.append(wc.country_request(name, t, lev))
    for lat, lon in spots:
        population.append(wc.timeseries_request(lat, lon, 0.0,
                                                3 * 3600.0))
        population.append(wc.profile_request(lat, lon))
    return population


def paris_newyork_path(cube: WeatherCube, n_wp: int = 8) -> np.ndarray:
    """Great-circle-ish Paris→New York descent/climb profile."""
    lats = np.linspace(48.85, 40.7, n_wp)
    lons = np.linspace(2.35, -74.0, n_wp)
    levels = np.concatenate([
        np.linspace(0, cube.n_levels - 1, n_wp // 2),
        np.linspace(cube.n_levels - 1, 0, n_wp - n_wp // 2)])
    times = np.linspace(0, (cube.n_times - 1) * 3600.0, n_wp)
    return np.stack([times, levels, lats, lons], axis=1)
