"""Graph data plane: CSR graphs, neighbour sampling, molecule batches.

The neighbour sampler is the Polytope view of graph access: a node's
neighbourhood is a contiguous CSR row range (an ordered-axis run), and a
fanout sample reads exactly the sampled entries — never full adjacency
rows of untouched nodes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray       # (N+1,)
    indices: np.ndarray      # (E,)
    node_feat: np.ndarray    # (N, F)
    labels: np.ndarray       # (N,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)


def synthetic_graph(n_nodes: int, avg_degree: int, d_feat: int,
                    n_classes: int, seed: int = 0) -> CSRGraph:
    """Power-law-ish graph whose labels correlate with features —
    a GNN can actually learn on it."""
    rng = np.random.default_rng(seed)
    # heavy-tailed out-degrees
    deg = np.minimum(rng.zipf(1.7, n_nodes) + avg_degree // 2,
                     n_nodes - 1)
    scale = n_nodes * avg_degree / deg.sum()
    deg = np.maximum(1, (deg * scale).astype(np.int64))
    indptr = np.concatenate([[0], np.cumsum(deg)])
    centers = rng.normal(0, 1, (n_classes, d_feat))
    labels = rng.integers(0, n_classes, n_nodes)
    feat = centers[labels] + rng.normal(0, 1.0, (n_nodes, d_feat))
    # homophilous edges: mostly within-class
    indices = np.empty(indptr[-1], np.int64)
    class_nodes = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for v in range(n_nodes):
        k = deg[v]
        same = class_nodes[labels[v]]
        n_same = max(1, int(0.7 * k))
        pick_same = same[rng.integers(0, len(same), n_same)]
        pick_rand = rng.integers(0, n_nodes, k - n_same)
        indices[indptr[v]:indptr[v + 1]] = np.concatenate(
            [pick_same, pick_rand])
    return CSRGraph(indptr.astype(np.int64), indices,
                    feat.astype(np.float32), labels.astype(np.int64))


def full_graph_batch(g: CSRGraph, pad_nodes: int, pad_edges: int,
                     train_frac: float = 0.6, seed: int = 0) -> dict:
    """Full-batch training tensors, padded to static shapes."""
    rng = np.random.default_rng(seed)
    n, e = g.n_nodes, g.n_edges
    src = np.repeat(np.arange(n), np.diff(g.indptr))
    dst = g.indices
    ei = np.full((2, pad_edges), -1, np.int32)
    ei[0, :e] = src[:pad_edges] if e > pad_edges else src
    ei[1, :e] = dst[:pad_edges] if e > pad_edges else dst
    feat = np.zeros((pad_nodes, g.node_feat.shape[1]), np.float32)
    feat[:n] = g.node_feat
    labels = np.zeros(pad_nodes, np.int64)
    labels[:n] = g.labels
    mask = np.zeros(pad_nodes, np.float32)
    train = rng.random(n) < train_frac
    mask[:n] = train
    pos = rng.normal(0, 1.5, (pad_nodes, 3)).astype(np.float32)
    return {"node_feat": feat, "positions": pos,
            "edge_index": ei, "labels": labels.astype(np.int32),
            "label_mask": mask}


def sample_neighbors(g: CSRGraph, seeds: np.ndarray,
                     fanouts: list[int], rng: np.random.Generator
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Layer-wise uniform neighbour sampling (GraphSAGE style).

    Returns (nodes, edge_index) where edge_index references positions in
    ``nodes``.  Each hop reads only the sampled CSR entries — the
    extraction plan over the adjacency datacube."""
    nodes = list(seeds)
    node_pos = {int(v): i for i, v in enumerate(seeds)}
    edges_src, edges_dst = [], []
    frontier = seeds
    for fanout in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            if hi == lo:
                continue
            take = rng.integers(lo, hi, min(fanout, hi - lo))
            for t in take:
                u = int(g.indices[t])
                if u not in node_pos:
                    node_pos[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                edges_src.append(node_pos[u])
                edges_dst.append(node_pos[int(v)])
        frontier = np.asarray(nxt, np.int64) if nxt else \
            np.empty(0, np.int64)
    ei = np.stack([np.asarray(edges_src, np.int64),
                   np.asarray(edges_dst, np.int64)])
    return np.asarray(nodes, np.int64), ei


def minibatch(g: CSRGraph, batch_nodes: int, fanouts: list[int],
              pad_nodes: int, pad_edges: int, step: int = 0) -> dict:
    rng = np.random.default_rng(step)
    seeds = rng.choice(g.n_nodes, batch_nodes, replace=False)
    nodes, ei = sample_neighbors(g, seeds, fanouts, rng)
    nodes = nodes[:pad_nodes]
    keep = (ei[0] < pad_nodes) & (ei[1] < pad_nodes)
    ei = ei[:, keep][:, :pad_edges]
    feat = np.zeros((pad_nodes, g.node_feat.shape[1]), np.float32)
    feat[:len(nodes)] = g.node_feat[nodes]
    labels = np.zeros(pad_nodes, np.int32)
    labels[:len(nodes)] = g.labels[nodes]
    mask = np.zeros(pad_nodes, np.float32)
    mask[:min(batch_nodes, pad_nodes)] = 1.0      # loss on seeds only
    ei_pad = np.full((2, pad_edges), -1, np.int32)
    ei_pad[:, :ei.shape[1]] = ei
    pos = np.random.default_rng(step + 1).normal(
        0, 1.5, (pad_nodes, 3)).astype(np.float32)
    return {"node_feat": feat, "positions": pos, "edge_index": ei_pad,
            "labels": labels, "label_mask": mask}


def molecule_batch(n_graphs: int, nodes_per: int = 30,
                   edges_per: int = 64, n_species: int = 16,
                   pad_nodes: int | None = None,
                   pad_edges: int | None = None, step: int = 0) -> dict:
    """Batched small molecules with a synthetic (smooth, E(3)-invariant)
    energy: sum of pairwise Morse-like terms — learnable target."""
    rng = np.random.default_rng(step)
    n_tot = n_graphs * nodes_per
    pad_nodes = pad_nodes or n_tot
    pad_edges = pad_edges or n_graphs * edges_per
    pos = rng.uniform(0, 4.0, (n_tot, 3)).astype(np.float32)
    species = rng.integers(0, n_species, n_tot)
    feat = np.eye(n_species, dtype=np.float32)[species]
    gid = np.repeat(np.arange(n_graphs), nodes_per).astype(np.int32)

    src = np.concatenate([
        g * nodes_per + rng.integers(0, nodes_per, edges_per)
        for g in range(n_graphs)])
    dst = np.concatenate([
        g * nodes_per + rng.integers(0, nodes_per, edges_per)
        for g in range(n_graphs)])
    energy = np.zeros(n_graphs, np.float32)
    for g in range(n_graphs):
        sel = slice(g * nodes_per, (g + 1) * nodes_per)
        d = np.linalg.norm(pos[sel][:, None] - pos[sel][None], axis=-1)
        iu = np.triu_indices(nodes_per, 1)
        r = d[iu]
        energy[g] = np.sum(np.exp(-2 * (r - 1.5) ** 2) -
                           0.5 * np.exp(-(r - 2.5) ** 2))

    ei = np.full((2, pad_edges), -1, np.int32)
    ei[0, :len(src)] = src
    ei[1, :len(dst)] = dst
    node_feat = np.zeros((pad_nodes, n_species), np.float32)
    node_feat[:n_tot] = feat
    positions = np.zeros((pad_nodes, 3), np.float32)
    positions[:n_tot] = pos
    gids = np.zeros(pad_nodes, np.int32)
    gids[:n_tot] = gid
    return {"node_feat": node_feat, "positions": positions,
            "edge_index": ei, "graph_ids": gids, "energy": energy,
            "forces": None, "n_graphs": n_graphs}
