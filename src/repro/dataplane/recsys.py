"""RecSys data plane: synthetic click logs with a learnable CTR model.

Sparse ids are Zipf-distributed (like real categorical traffic); labels
come from a hidden low-rank logistic model so the recsys architectures
actually converge in the examples/tests.  Lookup traffic then flows
through the EmbeddingBag extraction path (the paper's categorical-axis
plan-then-gather).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ClickStream:
    n_sparse: int = 26
    n_dense: int = 13
    rows: int = 1_000_000
    bag_size: int = 1
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._field_w = rng.normal(0, 1.0, (self.n_sparse, 8))
        self._row_emb_seed = rng.integers(2 ** 31)
        self._dense_w = rng.normal(0, 0.5, self.n_dense)

    def _row_latent(self, field: int, ids: np.ndarray) -> np.ndarray:
        # hash-based pseudo-embedding of each sparse id (deterministic)
        h = (ids.astype(np.int64) * 2654435761 + field * 97) % 104729
        return np.stack([np.sin(h * (k + 1) * 1e-3) for k in range(8)],
                        axis=-1)

    def batch(self, step: int, batch_size: int, shard: int = 0,
              n_shards: int = 1) -> dict:
        rng = np.random.default_rng(step * 104_729 + shard + self.seed)
        rows = batch_size // n_shards
        # Zipf ids clipped to vocab
        bags = np.minimum(rng.zipf(1.3, (rows, self.n_sparse,
                                         self.bag_size)) - 1,
                          self.rows - 1).astype(np.int32)
        dense = rng.normal(0, 1, (rows, self.n_dense)).astype(np.float32)
        logit = dense @ self._dense_w
        for f in range(self.n_sparse):
            lat = self._row_latent(f, bags[:, f, 0])
            logit = logit + lat @ self._field_w[f] / self.n_sparse
        p = 1 / (1 + np.exp(-logit))
        labels = (rng.random(rows) < p).astype(np.float32)
        return {"dense": dense, "bags": bags, "labels": labels}


@dataclass
class InteractionStream:
    """User→item interactions for retrieval / sequence models."""

    n_users: int = 1_000_000
    n_items: int = 1_000_000
    n_clusters: int = 64
    seed: int = 0

    def pairs(self, step: int, batch_size: int) -> dict:
        """Positive (user, item) pairs with cluster structure + logQ."""
        rng = np.random.default_rng(step * 7 + self.seed)
        users = rng.integers(0, self.n_users, batch_size)
        cluster = users % self.n_clusters
        items = (cluster * (self.n_items // self.n_clusters)
                 + rng.integers(0, self.n_items // self.n_clusters,
                                batch_size))
        # Zipf sampling prob estimate for logQ correction
        logq = -np.log1p(items.astype(np.float64))
        return {"user_ids": users.astype(np.int32),
                "item_ids": items.astype(np.int32),
                "item_logq": logq.astype(np.float32)}

    def sequences(self, step: int, batch_size: int, seq_len: int,
                  mask_prob: float = 0.2,
                  mask_token: int | None = None) -> dict:
        """Cloze-masked item sequences for BERT4Rec (Markov browsing)."""
        rng = np.random.default_rng(step * 13 + self.seed)
        mask_token = mask_token if mask_token is not None else \
            self.n_items
        items = np.empty((batch_size, seq_len), np.int64)
        items[:, 0] = rng.integers(0, self.n_items, batch_size)
        for t in range(1, seq_len):
            stay = rng.random(batch_size) < 0.8
            items[:, t] = np.where(
                stay, (items[:, t - 1] * 31 + 7) % self.n_items,
                rng.integers(0, self.n_items, batch_size))
        labels = items.copy()
        mask = rng.random((batch_size, seq_len)) < mask_prob
        inputs = np.where(mask, mask_token, items)
        return {"items": inputs.astype(np.int32),
                "labels": labels.astype(np.int32),
                "mask": mask.astype(np.float32)}
