# Pallas TPU kernels for the framework's compute hot spots.  Each
# subpackage is <name>/{kernel.py, ops.py, ref.py}: pl.pallas_call with
# explicit BlockSpec VMEM tiling, a jit'd dispatching wrapper, and the
# pure-jnp oracle the tests assert against.
#
# gather     — exact-byte extraction gather + fused EmbeddingBag (the
#              paper's I/O path on TPU: scalar-prefetch DMA of planned rows)
# slice      — batched polytope-hyperplane slicing (one BFS layer of
#              Algorithm 1 per launch)
# paged_attn — decode attention reading only planner-named KV pages
# segment    — segment-sum as one-hot MXU matmul (GNN / bag aggregation)
#
# _casting.checked_cast_i32 is the ONLY place an offset-carrying array
# may be cast to the kernels' int32 index dtype (enforced by the
# unchecked-i32-cast lint rule in repro.analysis).
from . import gather, paged_attn, segment, slice  # noqa: F401
from ._casting import checked_cast_i32, ensure_i32_addressable

__all__ = ["gather", "paged_attn", "segment", "slice",
           "checked_cast_i32", "ensure_i32_addressable"]
