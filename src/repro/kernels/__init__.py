# Pallas TPU kernels for the framework's compute hot spots.  Each
# subpackage is <name>/{kernel.py, ops.py, ref.py}: pl.pallas_call with
# explicit BlockSpec VMEM tiling, a jit'd dispatching wrapper, and the
# pure-jnp oracle the tests assert against.
#
# gather     — exact-byte extraction gather + fused EmbeddingBag (the
#              paper's I/O path on TPU: scalar-prefetch DMA of planned rows)
#              + run-length burst gather over coalesced plan runs
# slice      — batched polytope-hyperplane slicing (one BFS layer of
#              Algorithm 1 per launch)
# plan       — persistent device-resident BFS planning: the full
#              Algorithm-1 trailing stage (slice → compact → run
#              emission) in one pipeline invocation
# paged_attn — decode attention reading only planner-named KV pages
# segment    — segment-sum as one-hot MXU matmul (GNN / bag aggregation)
#
# _casting.checked_cast_i32 is the ONLY place an offset-carrying array
# may be cast to the kernels' int32 index dtype (enforced by the
# unchecked-i32-cast lint rule in repro.analysis).
from . import gather, paged_attn, plan, segment, slice  # noqa: F401
from ._casting import checked_cast_i32, ensure_i32_addressable

__all__ = ["gather", "paged_attn", "plan", "segment", "slice",
           "checked_cast_i32", "ensure_i32_addressable"]
