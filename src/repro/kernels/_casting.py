"""Bounds-checked int32 casts for offset-carrying arrays.

Pallas TPU scalar-prefetch indices are int32, so every kernel entry
point casts its offsets/indices down from the planner's int64.  On a
>2³¹-element datacube that cast silently truncates — the exact
byte-exactness bug the paper's contract forbids, and one no small-cube
test ever catches.  This module is the single place the cast is allowed
to happen (enforced by the ``unchecked-i32-cast`` lint rule in
``repro.analysis``): validation runs host-side, before trace, and raises
a clear error naming the cube size instead of reading the wrong bytes.

Inside a ``jit`` trace the values are tracers and cannot be inspected;
there the cast passes through unchecked, which is why callers with
static shape knowledge (e.g. ``core/batched.py``) must additionally call
:func:`ensure_i32_addressable` on the element count — that check runs at
trace time against concrete Python ints.
"""

from __future__ import annotations

import numpy as np

I32_LIMIT = 2 ** 31


def ensure_i32_addressable(n_elements: int, what: str = "datacube") -> None:
    """Raise unless every offset in ``[0, n_elements)`` fits in int32.

    Call with static sizes before building kernels whose index maps are
    int32 — runs at trace time, so it guards jitted code too.
    """
    if n_elements > I32_LIMIT:
        raise OverflowError(
            f"{what} has {n_elements} elements; offsets up to "
            f"{n_elements - 1} do not fit in int32 (limit {I32_LIMIT - 1}). "
            f"Shard the cube or keep offsets int64 host-side before "
            f"kernels consume them.")


def _is_tracer(x) -> bool:
    try:
        import jax

        return isinstance(x, jax.core.Tracer)
    except ImportError:
        return False


def checked_cast_i32(indices, *, what: str = "offsets",
                     n_elements: int | None = None,
                     allow_negative_one: bool = False):
    """Cast ``indices`` to int32 after validating they fit.

    ``n_elements``        — when given, offsets must be < n_elements
                            (and the cube itself must be i32-addressable).
    ``allow_negative_one`` — permit the kernels' ``-1`` padding slots
                            (EmbeddingBag bags, batched plan lattices).

    Concrete inputs (numpy or non-traced jax arrays) are validated
    host-side; tracers pass through (see module docstring).
    """
    if n_elements is not None:
        ensure_i32_addressable(n_elements, what=f"{what}: index space")
    if _is_tracer(indices):
        import jax.numpy as jnp

        return indices.astype(jnp.int32)  # lint-ok: unchecked-i32-cast
    arr = np.asarray(indices)
    if arr.size:
        hi = int(arr.max())
        lo = int(arr.min())
        if hi >= I32_LIMIT:
            space = (f" (index space has {n_elements} elements)"
                     if n_elements is not None else "")
            raise OverflowError(
                f"{what}: max offset {hi} does not fit in int32 "
                f"(limit {I32_LIMIT - 1}){space} — the int32 cast before "
                f"the gather kernel would silently read the wrong bytes.")
        if n_elements is not None and hi >= n_elements:
            raise IndexError(
                f"{what}: offset {hi} out of bounds for an index space "
                f"of {n_elements} elements.")
        floor = -1 if allow_negative_one else 0
        if lo < floor:
            raise IndexError(
                f"{what}: negative offset {lo} "
                f"({'only -1 padding is' if allow_negative_one else 'none'}"
                f" allowed).")
    if isinstance(indices, np.ndarray):
        return indices.astype(np.int32)  # lint-ok: unchecked-i32-cast
    import jax.numpy as jnp

    return indices.astype(jnp.int32)  # lint-ok: unchecked-i32-cast
