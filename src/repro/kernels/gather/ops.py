"""Public jit'd entry points for extraction gathers.

``use_pallas`` selects the Pallas kernel (interpret=True on CPU — the
kernel body runs in Python for validation; on TPU pass
``interpret=False``).  The default dispatch keeps the pure-jnp path for
host-only runs so the whole framework works identically with or without
the kernels — kernels are an optimisation layer, not a dependency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def gather_rows(table: jax.Array, indices: jax.Array,
                use_pallas: bool = False,
                interpret: bool = True) -> jax.Array:
    if use_pallas:
        return kernel.gather_rows(table, indices, interpret=interpret)
    return ref.gather_rows(table, indices)


def gather_rows_bag(table: jax.Array, bags: jax.Array,
                    use_pallas: bool = False,
                    interpret: bool = True) -> jax.Array:
    if use_pallas:
        return kernel.gather_rows_bag(table, bags, interpret=interpret)
    return ref.gather_rows_bag(table, bags)


def gather_plan_rows(flat: jax.Array, offsets: jax.Array, row: int,
                     use_pallas: bool = False) -> jax.Array:
    """Extraction-plan adapter: gather `row`-sized blocks from a flat
    datacube payload.  ``offsets`` are block-aligned element offsets from
    :class:`repro.core.ExtractionPlan` (``run_starts`` coalesced to
    ``row``-element blocks)."""
    n = flat.shape[0] // row
    table = flat[: n * row].reshape(n, row)
    return gather_rows(table, offsets // row, use_pallas=use_pallas)
