"""Public jit'd entry points for extraction gathers.

``use_pallas`` selects the Pallas kernel (interpret=True on CPU — the
kernel body runs in Python for validation; on TPU pass
``interpret=False``).  The default dispatch keeps the pure-jnp path for
host-only runs so the whole framework works identically with or without
the kernels — kernels are an optimisation layer, not a dependency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels._casting import checked_cast_i32

from . import kernel, ref

# Burst chunk width in elements: one DMA per chunk; runs longer than
# this split into several wide copies, shorter ones over-read into the
# padded tail and compact afterwards.
BURST_BLOCK = 128


def gather_rows(table: jax.Array, indices: jax.Array,
                use_pallas: bool = False,
                interpret: bool = True) -> jax.Array:
    if use_pallas:
        return kernel.gather_rows(table, indices, interpret=interpret)
    return ref.gather_rows(table, indices)


def gather_rows_bag(table: jax.Array, bags: jax.Array,
                    use_pallas: bool = False,
                    interpret: bool = True) -> jax.Array:
    if use_pallas:
        return kernel.gather_rows_bag(table, bags, interpret=interpret)
    return ref.gather_rows_bag(table, bags)


def chunk_runs(run_starts: np.ndarray, run_lengths: np.ndarray,
               block: int = BURST_BLOCK
               ) -> tuple[np.ndarray, np.ndarray]:
    """Split coalesced plan runs into ≤``block``-element DMA chunks.

    Pure numpy (host side — plan post-processing, not kernel work).
    Returns (chunk_starts (C,) int64, gather_idx (N,) int64): chunk c
    covers elements [chunk_starts[c], chunk_starts[c] + block) of the
    padded payload, and ``gather_idx`` compacts the (C·block,) chunk
    lattice back to the plan's N points in offset order.
    """
    starts = np.asarray(run_starts, np.int64)
    lens = np.asarray(run_lengths, np.int64)
    if starts.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    n_chunks = -(-lens // block)
    tot = int(n_chunks.sum())
    ends = np.cumsum(n_chunks)
    ordinal = np.arange(tot) - np.repeat(ends - n_chunks, n_chunks)
    chunk_starts = np.repeat(starts, n_chunks) + ordinal * block
    chunk_lens = np.minimum(block, np.repeat(lens, n_chunks)
                            - ordinal * block)
    cends = np.cumsum(chunk_lens)
    n = int(cends[-1])
    ramp = np.arange(n) - np.repeat(cends - chunk_lens, chunk_lens)
    gather_idx = np.repeat(np.arange(tot) * block, chunk_lens) + ramp
    return chunk_starts, gather_idx


def gather_plan_runs(flat: jax.Array, run_starts: np.ndarray,
                     run_lengths: np.ndarray, block: int = BURST_BLOCK,
                     use_pallas: bool = False,
                     interpret: bool = True) -> jax.Array:
    """Run-length-aware burst gather of an extraction plan.

    Reads every planned element of the flat (n,) payload as wide
    contiguous copies — one DMA per ≤``block``-element chunk of each
    coalesced run — then compacts the chunk lattice back to the plan's
    point order.  Byte-equal to ``flat[plan.offsets]``.
    """
    chunk_starts, gather_idx = chunk_runs(run_starts, run_lengths, block)
    if chunk_starts.size == 0:
        return jnp.zeros((0,), flat.dtype)
    n_flat = flat.shape[0]
    cs = checked_cast_i32(chunk_starts, what="burst gather chunk starts",
                          n_elements=n_flat)
    # pad so the final chunk's wide window stays in bounds
    flat_pad = jnp.concatenate([flat, jnp.zeros((block,), flat.dtype)])
    if use_pallas:
        out = kernel.gather_runs(flat_pad, cs, block, interpret=interpret)
    else:
        out = ref.gather_runs(flat_pad, cs, block)
    idx = checked_cast_i32(gather_idx,
                           what="burst gather compaction indices",
                           n_elements=out.size)
    return jnp.take(out.reshape(-1), idx)


def gather_plan_rows(flat: jax.Array, offsets: jax.Array, row: int,
                     use_pallas: bool = False) -> jax.Array:
    """Extraction-plan adapter: gather `row`-sized blocks from a flat
    datacube payload.  ``offsets`` are block-aligned element offsets from
    :class:`repro.core.ExtractionPlan` (``run_starts`` coalesced to
    ``row``-element blocks)."""
    n = flat.shape[0] // row
    table = flat[: n * row].reshape(n, row)
    return gather_rows(table, offsets // row, use_pallas=use_pallas)
