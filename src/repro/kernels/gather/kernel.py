"""Pallas TPU kernels for exact-byte extraction gathers.

This is the paper's contribution mapped onto the TPU memory hierarchy
(DESIGN.md §3): the Polytope planner has already computed *which* rows
are needed; these kernels DMA exactly those rows HBM→VMEM using
scalar-prefetched indices (`PrefetchScalarGridSpec`), never touching the
rest of the datacube — the bounding-box baseline would stream the whole
enclosing block.

Two kernels:

* ``gather_rows``     — (N, D) table × (M,) indices → (M, D).
  Grid step ``i`` DMAs table row ``idx[i]``; the index map *is* the
  extraction plan.
* ``gather_rows_bag`` — fused EmbeddingBag: (B, L) padded index bags →
  (B, D) segment-sum, accumulating over the L grid axis in the revisited
  output block (TPU grids execute sequentially, so output revisiting is
  the idiomatic reduction).

Both use block shape (BLOCK_ROWS, D): D is the datacube's minor storage
axis, so each DMA is one contiguous burst — the HBM analogue of the
paper's coalesced byte-run reads (``ExtractionPlan.run_starts``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._casting import checked_cast_i32


def _gather_kernel(idx_ref, table_ref, out_ref):
    # table_ref is the (1, D) row selected by the index map — the DMA
    # already read exactly the planned bytes; just move it to the output.
    out_ref[...] = table_ref[...]


def gather_rows(table: jax.Array, indices: jax.Array,
                interpret: bool = True) -> jax.Array:
    """Gather ``table[indices]`` reading only the planned rows.

    table   — (N, D)
    indices — (M,) integer, each in [0, N); validated host-side and cast
    to the int32 the scalar-prefetch index map requires (offsets past
    2³¹ raise instead of truncating).
    """
    indices = checked_cast_i32(indices, what="gather_rows indices",
                               n_elements=table.shape[0])
    return _gather_rows(table, indices, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gather_rows(table: jax.Array, indices: jax.Array,
                 interpret: bool = True) -> jax.Array:
    n, d = table.shape
    m = indices.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), table.dtype),
        interpret=interpret,
        name="polytope_gather_rows",
    )(indices, table)


def _bag_kernel(idx_ref, table_ref, out_ref):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Padded slots carry index -1 → contribute zero.
    valid = idx_ref[b, l] >= 0
    row = table_ref[...]
    out_ref[...] += jnp.where(valid, row, jnp.zeros_like(row))


def gather_rows_bag(table: jax.Array, bags: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """Fused EmbeddingBag(sum): out[b] = Σ_l table[bags[b, l]].

    table — (N, D);  bags — (B, L) integer, padded with -1 (the only
    negative value allowed; validated host-side before the int32 cast).
    """
    bags32 = checked_cast_i32(bags, what="gather_rows_bag bags",
                              n_elements=table.shape[0],
                              allow_negative_one=True)
    return _gather_rows_bag(table, bags32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gather_rows_bag(table: jax.Array, bags32: jax.Array,
                     interpret: bool = True) -> jax.Array:
    n, d = table.shape
    b, l = bags32.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, l),
        in_specs=[
            # clamp -1 padding to row 0; the kernel masks it out.
            pl.BlockSpec((1, d),
                         lambda i, j, idx: (jnp.maximum(idx[i, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, idx: (i, 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
        name="polytope_gather_bag",
    )(bags32, table)


def _runs_kernel(starts_ref, flat_ref, out_ref, scratch_ref, sem, *,
                 block: int):
    # One grid step = one coalesced plan run chunk: a single wide DMA
    # HBM→VMEM starting at the scalar-prefetched element offset.  This
    # is the run-length-aware burst path — per-offset gathers become one
    # `block`-wide copy per chunk.
    i = pl.program_id(0)
    start = starts_ref[i]
    copy = pltpu.make_async_copy(flat_ref.at[pl.ds(start, block)],
                                 scratch_ref, sem)
    copy.start()
    copy.wait()
    out_ref[...] = scratch_ref[...][None, :]


def gather_runs(flat: jax.Array, chunk_starts: jax.Array,
                block: int, interpret: bool = True) -> jax.Array:
    """Burst-gather ``block`` contiguous elements per chunk start.

    flat         — (n + block,) payload, padded by ``block`` so the last
                   chunk's wide copy stays in bounds
    chunk_starts — (C,) element offsets; validated and cast by the
                   caller (``ops.gather_plan_runs``)
    Returns (C, block); callers compact the valid prefix of each chunk.
    """
    chunk_starts = checked_cast_i32(chunk_starts,
                                    what="gather_runs chunk starts",
                                    n_elements=flat.shape[0])
    return _gather_runs(flat, chunk_starts, block=block,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _gather_runs(flat: jax.Array, chunk_starts: jax.Array, block: int,
                 interpret: bool = True) -> jax.Array:
    c = chunk_starts.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c,),
        in_specs=[
            # whole payload stays in HBM/ANY; the kernel DMAs slices
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, idx: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block,), flat.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(_runs_kernel, block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, block), flat.dtype),
        interpret=interpret,
        name="polytope_gather_runs",
    )(chunk_starts, flat)
