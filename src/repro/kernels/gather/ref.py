"""Pure-jnp oracles for the gather kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels._casting import checked_cast_i32


def gather_rows(table: jax.Array, indices: jax.Array) -> jax.Array:
    idx = checked_cast_i32(indices, what="gather_rows indices",
                           n_elements=table.shape[0])
    return jnp.take(table, idx, axis=0)


def gather_rows_bag(table: jax.Array, bags: jax.Array) -> jax.Array:
    """EmbeddingBag(sum) with -1 padding."""
    bags = checked_cast_i32(bags, what="gather_rows_bag bags",
                            n_elements=table.shape[0],
                            allow_negative_one=True)
    valid = (bags >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(bags, 0), axis=0)
    return jnp.sum(jnp.where(valid, rows, 0), axis=1).astype(table.dtype)


def gather_runs(flat: jax.Array, chunk_starts: jax.Array,
                block: int) -> jax.Array:
    """Oracle for the burst kernel: strided window loads, (C, block)."""
    starts = checked_cast_i32(chunk_starts, what="gather_runs chunk starts",
                              n_elements=flat.shape[0])
    window = starts[:, None] + jnp.arange(block, dtype=jnp.int32)[None, :]
    return jnp.take(flat, window, axis=0)
