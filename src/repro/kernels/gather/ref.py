"""Pure-jnp oracles for the gather kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows(table: jax.Array, indices: jax.Array) -> jax.Array:
    return jnp.take(table, indices.astype(jnp.int32), axis=0)


def gather_rows_bag(table: jax.Array, bags: jax.Array) -> jax.Array:
    """EmbeddingBag(sum) with -1 padding."""
    valid = (bags >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(bags, 0).astype(jnp.int32), axis=0)
    return jnp.sum(jnp.where(valid, rows, 0), axis=1).astype(table.dtype)
