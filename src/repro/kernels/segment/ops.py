"""Dispatching segment reduction: Pallas for VMEM-resident accumulators,
XLA segment_sum otherwise."""

from __future__ import annotations

import jax

from . import kernel, ref

VMEM_SEGMENT_LIMIT = 512 * 1024  # floats of (S, D) accumulator


def segment_sum(messages: jax.Array, segment_ids: jax.Array,
                num_segments: int, use_pallas: bool = False,
                interpret: bool = True) -> jax.Array:
    d = messages.shape[-1]
    if use_pallas and num_segments * d <= VMEM_SEGMENT_LIMIT:
        return kernel.segment_sum(messages, segment_ids, num_segments,
                                  interpret=interpret)
    return ref.segment_sum(messages, segment_ids, num_segments)


def segment_max(messages: jax.Array, segment_ids: jax.Array,
                num_segments: int, **_) -> jax.Array:
    return ref.segment_max(messages, segment_ids, num_segments)
