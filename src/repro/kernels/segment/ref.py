"""Pure-jnp oracle for tiled segment reduction."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(messages: jax.Array, segment_ids: jax.Array,
                num_segments: int) -> jax.Array:
    """out[s] = Σ_{e: seg[e]==s} messages[e].  seg<0 entries are dropped."""
    valid = segment_ids >= 0
    msg = jnp.where(valid[:, None], messages, 0)
    seg = jnp.where(valid, segment_ids, 0)
    return jax.ops.segment_sum(msg, seg, num_segments=num_segments)


def segment_max(messages: jax.Array, segment_ids: jax.Array,
                num_segments: int) -> jax.Array:
    neg = jnp.full_like(messages, -jnp.inf)
    valid = segment_ids >= 0
    msg = jnp.where(valid[:, None], messages, neg)
    seg = jnp.where(valid, segment_ids, 0)
    out = jax.ops.segment_max(msg, seg, num_segments=num_segments)
    return jnp.where(jnp.isfinite(out), out, 0.0)
