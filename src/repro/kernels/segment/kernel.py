"""Pallas TPU kernel: segment-sum as a one-hot MXU matmul.

TPU has no scatter unit; the idiomatic TPU scatter-add is
``onehot(seg_ids) @ messages`` — a (S × E_blk) × (E_blk × D) matmul per
edge block, accumulated into the revisited (S, D) output block.  The
MXU turns the GNN aggregation (and EmbeddingBag epilogues) into dense
systolic work (DESIGN.md §3 hardware adaptation: scatter → matmul).

Constraint: the full (num_segments, D) accumulator lives in VMEM, so
this kernel serves minibatch/molecule regimes (S·D ≲ 512k floats).
Full-graph regimes keep `jax.ops.segment_sum` (XLA handles HBM-resident
scatter); the dispatch in ops.py chooses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._casting import checked_cast_i32

BLOCK_E = 256


def _segment_sum_kernel(seg_ref, msg_ref, out_ref, *, num_segments: int,
                        n_blocks: int):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                               # (BLOCK_E,)
    msg = msg_ref[...].astype(jnp.float32)           # (BLOCK_E, D)
    valid = seg >= 0
    seg_ids = jnp.where(valid, seg, 0)
    onehot = (seg_ids[None, :] == jax.lax.broadcasted_iota(
        jnp.int32, (num_segments, seg.shape[0]), 0))
    onehot = jnp.where(valid[None, :], onehot, False).astype(jnp.float32)
    out_ref[...] += (onehot @ msg).astype(out_ref.dtype)


def segment_sum(messages: jax.Array, segment_ids: jax.Array,
                num_segments: int, interpret: bool = True) -> jax.Array:
    """Validate segment ids host-side (each in [0, num_segments), ``-1``
    padding allowed), cast through the bounds-checked helper, then run
    the jitted one-hot MXU kernel; tracers pass through."""
    seg32 = checked_cast_i32(segment_ids, what="segment_sum segment_ids",
                             n_elements=num_segments,
                             allow_negative_one=True)
    return _segment_sum(messages, seg32, num_segments,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _segment_sum(messages: jax.Array, segment_ids: jax.Array,
                 num_segments: int, interpret: bool = True) -> jax.Array:
    e, d = messages.shape
    pad = (-e) % BLOCK_E
    if pad:
        messages = jnp.pad(messages, ((0, pad), (0, 0)))
        # -1 padding stays int32 — masked out inside the kernel
        segment_ids = jnp.pad(segment_ids, (0, pad), constant_values=-1)
    ee = messages.shape[0]
    n_blocks = ee // BLOCK_E

    return pl.pallas_call(
        functools.partial(_segment_sum_kernel, num_segments=num_segments,
                          n_blocks=n_blocks),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_E,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_E, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), messages.dtype),
        interpret=interpret,
        name="segment_sum_onehot_mxu",
    )(segment_ids, messages)
