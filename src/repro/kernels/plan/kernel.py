"""Pallas TPU kernel: persistent device-resident BFS planning pipeline.

One ``pallas_call`` executes the whole Algorithm-1 trailing stage: the
grid walks the jobs (leading-path × polytope pairs) and every step runs
slice → column ranges → run emission for its job, appending compacted
``(run_start, run_length)`` pairs directly into the plan buffer that
``kernels/gather`` scalar-prefetches.  Nothing returns to the host
between layers — the BFS frontier (candidate rows and their column
ranges) lives in registers/VMEM for exactly one grid step.

Persistence idiom (same as ``gather_rows_bag``): TPU grids execute
sequentially, so the outputs are *revisited* blocks — the run buffers
and a 3-word ``meta`` carry (``[cursor, n_rows, n_points]``) persist
across steps.  Each step compacts its local slots with an exclusive
prefix sum over the valid-run mask and scatters them at the carried
cursor; invalid slots scatter out of bounds and drop.  Because the
cursor advances in job order and the local scan preserves
(row, segment) order, the emitted buffer is byte-identical to the jnp
oracle's global compaction (``ref.plan_runs_2d``).

The per-job math is literally ``ref.row_slots_2d`` called on the
(1, V, 2) job block — the oracle and the kernel cannot drift.  CPU CI
runs interpret mode; the gathers (``sv0[rows]``) and the (M,)-buffer
read-modify-write are VMEM-resident on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import row_slots_2d


def _plan_kernel(verts_ref, valid_ref, base_ref, sv0_ref, rowoff0_ref,
                 sv1_ref, scalars_ref, starts_ref, lens_ref, meta_ref, *,
                 n0: int, n1: int, max_rows: int, cyclic: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        starts_ref[...] = jnp.zeros_like(starts_ref)
        lens_ref[...] = jnp.zeros_like(lens_ref)
        meta_ref[...] = jnp.zeros_like(meta_ref)

    starts, lengths, ok, n_rows, n_points = row_slots_2d(
        verts_ref[...], valid_ref[...], base_ref[...], sv0_ref[...],
        rowoff0_ref[...], sv1_ref[...], scalars_ref[...],
        n0=n0, n1=n1, max_rows=max_rows, cyclic=cyclic)

    # In-kernel compaction: exclusive prefix sum over the valid mask
    # gives each live slot its position after the carried cursor.
    s = 2 * max_rows
    ok_f = ok.reshape(s)
    tgt = jnp.cumsum(ok_f, dtype=jnp.int32) - ok_f
    meta = meta_ref[...]
    cursor = meta[0]
    m = starts_ref.shape[0]
    # dead slots scatter to index m — out of bounds, dropped
    pos = jnp.where(ok_f, cursor + tgt, m)
    starts_ref[...] = starts_ref[...].at[pos].set(
        jnp.where(ok_f, starts.reshape(s), 0))
    lens_ref[...] = lens_ref[...].at[pos].set(
        jnp.where(ok_f, lengths.reshape(s), 0))
    n_runs = jnp.sum(ok_f, dtype=jnp.int32)
    meta_ref[...] = meta + jnp.stack([n_runs, n_rows, n_points])


@functools.partial(jax.jit, static_argnames=(
    "n0", "n1", "max_rows", "cyclic", "interpret"))
def plan_runs_2d(verts, valid, base, sv0, rowoff0, sv1, scalars, *,
                 n0: int, n1: int, max_rows: int, cyclic: bool,
                 interpret: bool = True):
    """Device pipeline with the ``ref.plan_runs_2d`` contract:
    returns (run_starts (M,) i32, run_lengths (M,) i32, meta (3,) i32)
    with M = J · max_rows · 2, byte-identical to the oracle."""
    j, v, _ = verts.shape
    m = j * max_rows * 2
    if j == 0:
        zero = jnp.zeros((0,), jnp.int32)
        return zero, zero, jnp.zeros((3,), jnp.int32)

    return pl.pallas_call(
        functools.partial(_plan_kernel, n0=n0, n1=n1, max_rows=max_rows,
                          cyclic=cyclic),
        grid=(j,),
        in_specs=[
            pl.BlockSpec((1, v, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((n0,), lambda i: (0,)),
            pl.BlockSpec((n0,), lambda i: (0,)),
            pl.BlockSpec((n1,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((3,), jnp.int32),
        ],
        interpret=interpret,
        name="polytope_plan_runs",
    )(verts, valid, base, sv0, rowoff0, sv1, scalars)
