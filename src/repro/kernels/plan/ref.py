"""Pure-jnp oracle for the device-resident BFS planning pipeline.

Fuses the full trailing-2-D stage of Algorithm 1 — the part that
dominates cold-path planning — into ONE jitted computation, where the
host planner round-trips through Python per BFS layer:

  row discovery   per (job) polytope: extents on the major axis →
                  index range (comparison-count ``searchsorted``,
                  byte-identical to ``OrderedAxis.indices_in_range``);
  slice           every (job, row) pair at once, reduced to the minor-
                  coordinate extents (``kernels.slice
                  .slice_minor_extents`` — the shared slicing core);
  column ranges   minor-axis index ranges per row, with the cyclic
                  seam split (≤ 2 storage segments per row, mirroring
                  ``CyclicAxis.indices_in_range``);
  run emission    vector leaves become ``(run_start, run_length)``
                  pairs in storage offsets — the representation
                  ``kernels/gather`` burst-DMAs — compacted by an
                  exclusive prefix sum over the valid-run mask.

A *job* is one (leading-axis path × polytope) pair; ``base`` carries
the path's storage base offset, so the emitted runs are absolute.  The
frontier (the (J, R) row lattice and its per-row column ranges) never
materializes on the host: one invocation returns the compacted run
buffer plus the §5.2 slice accounting.

Numerics: every comparison/interpolation mirrors the host planner's
formulas operation-for-operation (``OrderedAxis.indices_in_range`` eps
widening, ``geometry.slice_vertices`` pairwise lerp), so under float64
inputs the emitted byte set is bit-identical to the host plan; under
float32 exactness holds whenever the geometry clears grid values by
more than f32 roundoff (the ``core/batched.py`` regime).

Layout: runs are compacted in flat slot order ``(job, row, segment)``
with segment 0 = the in-window range and segment 1 = the wrapped
(pre-seam) range, so the Pallas kernel's sequential-grid cursor and
this oracle produce byte-identical buffers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._casting import ensure_i32_addressable
from repro.kernels.slice.ref import slice_minor_extents

# scalars[] layout shared with the Pallas kernel
EPS0, EPS1, PLANE_TOL_REL, PERIOD = range(4)


def _count_lt(values: jax.Array, x: jax.Array) -> jax.Array:
    """# of ``values`` < x — ``searchsorted(side='left')`` as a
    comparison count (identical result, kernel-friendly)."""
    return jnp.sum(values < x[..., None], axis=-1, dtype=jnp.int32)


def _count_le(values: jax.Array, x: jax.Array) -> jax.Array:
    """# of ``values`` ≤ x — ``searchsorted(side='right')``."""
    return jnp.sum(values <= x[..., None], axis=-1, dtype=jnp.int32)


def row_slots_2d(verts, valid, base, sv0, rowoff0, sv1, scalars, *,
                 n0: int, n1: int, max_rows: int, cyclic: bool):
    """Uncompacted run slots for every (job, row): the device frontier.

    verts   — (J, V, 2) padded vertices, (major, minor) coordinates
    valid   — (J, V) vertex mask
    base    — (J,) int32 storage base offset of the job's leading path
    sv0     — (n0,) sorted major-axis values
    rowoff0 — (n0,) int32 storage offset of each sorted major index
              (precomputed host-side through the axis permutation and
              any transform, so merged/mapped major axes need no
              in-kernel address arithmetic)
    sv1     — (n1,) sorted minor-axis values (identity storage order,
              unit stride — the run-contiguity precondition)
    scalars — (4,) float: [eps0, eps1, plane_tol_rel, period]

    Returns (starts (J, R, 2) int32, lengths (J, R, 2) int32,
    ok (J, R, 2) bool, n_rows (), n_points ()): segment 0 is the
    in-window column range, segment 1 the wrapped pre-seam range
    (cyclic only).  ``n_rows`` counts candidate rows (the §5.2 dim-2
    slice count), ``n_points`` the emitted points pre-dedupe (dim-1).
    """
    ensure_i32_addressable(n0 * n1, what="plan_runs_2d trailing grid")
    R = max_rows
    fdt = verts.dtype
    big = jnp.asarray(jnp.inf, fdt)
    eps0 = scalars[EPS0]
    eps1 = scalars[EPS1]
    period = scalars[PERIOD]

    x = verts[:, :, 0]                                   # (J, V)
    y = verts[:, :, 1]

    # -- row discovery (Alg.1 lines 6-7 on the major axis) ---------------
    lo0 = jnp.min(jnp.where(valid, x, big), axis=1)      # (J,)
    hi0 = jnp.max(jnp.where(valid, x, -big), axis=1)
    i0 = _count_lt(sv0, lo0 - eps0)                      # (J,)
    i1 = _count_le(sv0, hi0 + eps0)
    r = i0[:, None] + jnp.arange(R, dtype=jnp.int32)[None, :]   # (J, R)
    row_ok = r < i1[:, None]
    rc = jnp.clip(r, 0, n0 - 1)
    rv = sv0[rc]                                         # (J, R) values
    row_off = base[:, None] + rowoff0[rc]                # (J, R) offsets

    # -- slice every (job, row) pair at once -----------------------------
    # Host parity: geometry.slice_vertices scales its on-plane tolerance
    # by max(1, |major coords|max) per polytope.
    scale = jnp.maximum(jnp.asarray(1.0, fdt),
                        jnp.max(jnp.where(valid, jnp.abs(x), 0.0), axis=1))
    tol = scalars[PLANE_TOL_REL] * scale                 # (J,)
    lo1, hi1, hit = slice_minor_extents(
        x[:, None, :], y[:, None, :], valid[:, None, :], rv, tol[:, None])

    # -- column ranges on the minor axis (≤ 2 storage segments) ----------
    if cyclic:
        whole = (hi1 - lo1) >= period                    # whole circle
        m = jnp.floor((lo1 - sv1[0]) / period)
        lo_s = lo1 - m * period
        hi_s = hi1 - m * period
        jA0 = jnp.where(whole, 0, _count_lt(sv1, lo_s - eps1))
        jA1 = jnp.where(whole, n1, _count_le(sv1, hi_s + eps1))
        jB1 = jnp.where(whole, 0, _count_le(sv1, hi_s - period + eps1))
    else:
        jA0 = _count_lt(sv1, lo1 - eps1)
        jA1 = _count_le(sv1, hi1 + eps1)
        jB1 = jnp.zeros_like(jA0)

    len_a = jnp.maximum(jA1 - jA0, 0)
    ok_a = row_ok & hit & (len_a > 0)
    len_b = jnp.maximum(jB1, 0)
    ok_b = row_ok & hit & (len_b > 0) if cyclic \
        else jnp.zeros_like(ok_a)

    starts = jnp.stack([row_off + jA0, row_off], axis=-1)       # (J, R, 2)
    lengths = jnp.stack([len_a, len_b], axis=-1)
    ok = jnp.stack([ok_a, ok_b], axis=-1)
    n_rows = jnp.sum(row_ok, dtype=jnp.int32)
    n_points = jnp.sum(jnp.where(ok, lengths, 0), dtype=jnp.int32)
    return starts, lengths, ok, n_rows, n_points


@functools.partial(jax.jit, static_argnames=("n0", "n1", "max_rows",
                                             "cyclic"))
def plan_runs_2d(verts, valid, base, sv0, rowoff0, sv1, scalars, *,
                 n0: int, n1: int, max_rows: int, cyclic: bool):
    """The fused pipeline: frontier → compacted run buffer, one call.

    Returns (run_starts (M,) int32, run_lengths (M,) int32,
    meta (3,) int32 = [n_runs, n_rows, n_points]) with
    M = J · max_rows · 2; slots past ``n_runs`` are zero.  Compaction
    is an exclusive prefix sum over the valid-run mask — the same
    scheme the Pallas kernel runs with its sequential-grid cursor, so
    both produce byte-identical buffers.
    """
    starts, lengths, ok, n_rows, n_points = row_slots_2d(
        verts, valid, base, sv0, rowoff0, sv1, scalars,
        n0=n0, n1=n1, max_rows=max_rows, cyclic=cyclic)
    m = starts.size
    ok_f = ok.reshape(m)
    tgt = jnp.cumsum(ok_f, dtype=jnp.int32) - ok_f       # exclusive scan
    # invalid slots scatter to the dropped tail slot m
    pos = jnp.where(ok_f, tgt, m)
    run_starts = jnp.zeros(m + 1, jnp.int32).at[pos].set(
        jnp.where(ok_f, starts.reshape(m), 0))[:m]
    run_lengths = jnp.zeros(m + 1, jnp.int32).at[pos].set(
        jnp.where(ok_f, lengths.reshape(m), 0))[:m]
    n_runs = jnp.sum(ok_f, dtype=jnp.int32)
    meta = jnp.stack([n_runs, n_rows, n_points])
    return run_starts, run_lengths, meta
