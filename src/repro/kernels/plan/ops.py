"""Public entry point for the fused planning pipeline.

``use_pallas`` selects the persistent Pallas pipeline (interpret=True on
CPU); the default is the pure-jnp oracle, which is the same fused
computation without the explicit grid — either way planning is ONE
device invocation instead of a host round-trip per BFS layer.
"""

from __future__ import annotations

from . import kernel, ref

EPS0 = ref.EPS0
EPS1 = ref.EPS1
PLANE_TOL_REL = ref.PLANE_TOL_REL
PERIOD = ref.PERIOD


def plan_runs_2d(verts, valid, base, sv0, rowoff0, sv1, scalars, *,
                 n0: int, n1: int, max_rows: int, cyclic: bool,
                 use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return kernel.plan_runs_2d(
            verts, valid, base, sv0, rowoff0, sv1, scalars,
            n0=n0, n1=n1, max_rows=max_rows, cyclic=cyclic,
            interpret=interpret)
    return ref.plan_runs_2d(
        verts, valid, base, sv0, rowoff0, sv1, scalars,
        n0=n0, n1=n1, max_rows=max_rows, cyclic=cyclic)
