"""Pallas TPU kernel: batched polytope-hyperplane slicing.

One grid step slices BLOCK_P polytopes against their planes — a BFS
layer of Algorithm 1 becomes a single kernel launch (DESIGN.md §3).
The math is pure VPU work (sign split, all-pairs lerp) on small tiles
that live entirely in VMEM: verts (BLOCK_P, V, D) plus the (V × V) pair
lattice.  V and D are tiny (≤ 32, ≤ 8), so the working set is a few KB
per step; the batch dimension P provides the parallelism.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PLANE_TOL

BLOCK_P = 8


def _slice_kernel(verts_ref, valid_ref, planes_ref, out_ref, mask_ref, *,
                  k: int):
    verts = verts_ref[...]                         # (BP, V, D)
    valid = valid_ref[...]                         # (BP, V)
    planes = planes_ref[...]                       # (BP,)
    bp, v, d = verts.shape

    c = planes[:, None]
    coord = verts[:, :, k]
    scale = jnp.maximum(1.0, jnp.max(jnp.abs(coord), axis=1, keepdims=True))
    big = jnp.asarray(1e30, verts.dtype)
    dist = jnp.where(valid, coord - c, big)

    on = (jnp.abs(dist) <= PLANE_TOL * scale) & valid
    below = (dist < -PLANE_TOL * scale) & valid
    above = (dist > PLANE_TOL * scale) & (dist < big) & valid

    on_pts = verts.at[:, :, k].set(jnp.broadcast_to(c, (bp, v)))

    di = jnp.where(below, dist, 0.0)[:, :, None]
    dj = jnp.where(above, dist, 0.0)[:, None, :]
    denom = di - dj
    t = jnp.where(jnp.abs(denom) > 0,
                  di / jnp.where(denom == 0, 1.0, denom), 0.0)
    vi = verts[:, :, None, :]
    vj = verts[:, None, :, :]
    interp = vi + t[..., None] * (vj - vi)
    interp = interp.at[:, :, :, k].set(
        jnp.broadcast_to(c[:, :, None], (bp, v, v)))
    pair_valid = below[:, :, None] & above[:, None, :]

    out = jnp.concatenate([on_pts, interp.reshape(bp, v * v, d)], axis=1)
    out_valid = jnp.concatenate([on, pair_valid.reshape(bp, v * v)], axis=1)
    out_ref[...] = jnp.where(out_valid[..., None], out, 0.0)
    mask_ref[...] = out_valid


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def slice_batch(verts: jax.Array, valid: jax.Array, planes: jax.Array,
                k: int, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    p, v, d = verts.shape
    pad = (-p) % BLOCK_P
    if pad:
        verts = jnp.pad(verts, ((0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        planes = jnp.pad(planes, (0, pad))
    pp = verts.shape[0]
    n_slots = v + v * v

    out, mask = pl.pallas_call(
        functools.partial(_slice_kernel, k=k),
        grid=(pp // BLOCK_P,),
        in_specs=[
            pl.BlockSpec((BLOCK_P, v, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_P, v), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_P, n_slots, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_P, n_slots), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pp, n_slots, d), verts.dtype),
            jax.ShapeDtypeStruct((pp, n_slots), jnp.bool_),
        ],
        interpret=interpret,
        name="polytope_slice_batch",
    )(verts, valid, planes)
    return out[:p], mask[:p]
