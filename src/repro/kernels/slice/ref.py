"""Pure-jnp oracle for batched polytope-hyperplane slicing.

Batched counterpart of :func:`repro.core.geometry.slice_vertices`: one
BFS layer of Algorithm 1 slices *every* (polytope, plane) pair at once
(DESIGN.md §3: "BFS layer = batch").

Layout (fixed shapes — TPU needs static sizes):
  verts  — (P, V, D) float32, padded vertices
  valid  — (P, V)    bool, vertex validity
  planes — (P,)      float32, slice plane position per polytope
  k      — static int, axis being sliced

Output: (P, V + V*V, D) candidate vertices + (P, V + V*V) validity.
Slot layout: first V slots are "vertex on plane" hits; slot V + i*V + j
is the interpolation between vertex i (below) and vertex j (above).
Downstream (host hull-prune or mask-aware consumers) compacts.
The sliced axis k keeps its coordinate (== plane) so D stays static;
callers drop it when rebuilding Polytope objects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

PLANE_TOL = 1e-6


@functools.partial(jax.jit, static_argnames=("k",))
def slice_batch(verts: jax.Array, valid: jax.Array, planes: jax.Array,
                k: int) -> tuple[jax.Array, jax.Array]:
    p, v, d = verts.shape
    c = planes[:, None]                              # (P, 1)
    coord = verts[:, :, k]                           # (P, V)
    scale = jnp.maximum(1.0, jnp.max(jnp.abs(coord), axis=1, keepdims=True))
    dist = jnp.where(valid, coord - c, jnp.inf)      # (P, V)

    on = (jnp.abs(dist) <= PLANE_TOL * scale) & valid
    below = (dist < -PLANE_TOL * scale) & valid
    above = (dist > PLANE_TOL * scale) & jnp.isfinite(dist) & valid

    # on-plane vertices, coordinate k snapped onto the plane
    on_pts = verts.at[:, :, k].set(jnp.broadcast_to(c, (p, v)))

    # all-pairs interpolation i(below) -> j(above)
    di = jnp.where(below, dist, 0.0)[:, :, None]         # (P, V, 1)
    dj = jnp.where(above, dist, 0.0)[:, None, :]         # (P, 1, V)
    denom = di - dj
    t = jnp.where(jnp.abs(denom) > 0, di / jnp.where(denom == 0, 1.0, denom),
                  0.0)                                   # (P, V, V)
    vi = verts[:, :, None, :]                            # (P, V, 1, D)
    vj = verts[:, None, :, :]                            # (P, 1, V, D)
    interp = vi + t[..., None] * (vj - vi)               # (P, V, V, D)
    interp = interp.at[:, :, :, k].set(jnp.broadcast_to(c[:, :, None],
                                                        (p, v, v)))
    pair_valid = below[:, :, None] & above[:, None, :]   # (P, V, V)

    out = jnp.concatenate([on_pts, interp.reshape(p, v * v, d)], axis=1)
    out_valid = jnp.concatenate([on, pair_valid.reshape(p, v * v)], axis=1)
    out = jnp.where(out_valid[..., None], out, 0.0)
    return out, out_valid
