"""Pure-jnp oracle for batched polytope-hyperplane slicing.

Batched counterpart of :func:`repro.core.geometry.slice_vertices`: one
BFS layer of Algorithm 1 slices *every* (polytope, plane) pair at once
(DESIGN.md §3: "BFS layer = batch").

Layout (fixed shapes — TPU needs static sizes):
  verts  — (P, V, D) float32, padded vertices
  valid  — (P, V)    bool, vertex validity
  planes — (P,)      float32, slice plane position per polytope
  k      — static int, axis being sliced

Output: (P, V + V*V, D) candidate vertices + (P, V + V*V) validity.
Slot layout: first V slots are "vertex on plane" hits; slot V + i*V + j
is the interpolation between vertex i (below) and vertex j (above).
Downstream (host hull-prune or mask-aware consumers) compacts.
The sliced axis k keeps its coordinate (== plane) so D stays static;
callers drop it when rebuilding Polytope objects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

PLANE_TOL = 1e-6


def slice_minor_extents(x: jax.Array, y: jax.Array, valid: jax.Array,
                        planes: jax.Array, tol_scaled: jax.Array
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Extents of the remaining coordinate after slicing, batched.

    The fused planning pipeline (``repro.kernels.plan``) never needs the
    sliced vertex *set* — only the min/max of the remaining coordinate
    (Algorithm 1 line 6 of the next layer).  This is the same sign-split
    + all-pairs-lerp math as :func:`slice_batch`, reduced to extents so
    the (V × V) candidate lattice never leaves registers.  Pure jnp on
    broadcastable shapes, so it runs identically at the top level (the
    jnp oracle, ``core/batched.py``) and inside a Pallas kernel body.

    x, y       — (..., V) sliced-axis / kept-axis vertex coordinates
    valid      — (..., V) vertex mask
    planes     — (...,)   slice plane per batch element
    tol_scaled — broadcastable to (...,): absolute on-plane tolerance
                 (callers scale: host parity wants
                 ``geometry.PLANE_TOL * max(1, |x|max)``, the f32 batch
                 path wants ``PLANE_TOL``-scaled)

    Returns (lo, hi, hit) of shape (...,): the kept-coordinate extents
    of the intersection and whether the plane hits at all.  ``lo``/``hi``
    are ±inf where ``hit`` is False.  Exactly mirrors the host
    ``geometry.slice_vertices`` candidate set: on-plane vertices keep
    their y; every (below, above) pair contributes
    ``y_i + t·(y_j − y_i)`` with ``t = d_i / (d_i − d_j)`` — min/max are
    unchanged by the host's hull prune and dedupe, so in float64 the
    extents match the host planner bit-for-bit.
    """
    big = jnp.asarray(jnp.inf, x.dtype)
    tol = jnp.asarray(tol_scaled, x.dtype)[..., None]
    d = jnp.where(valid, x - planes[..., None], big)      # (..., V)

    on = valid & (jnp.abs(d) <= tol)
    below = valid & (d < -tol)
    above = valid & (d > tol) & jnp.isfinite(d)

    y_on_lo = jnp.where(on, y, big)
    y_on_hi = jnp.where(on, y, -big)

    di = jnp.where(below, d, 0.0)[..., :, None]           # (..., V, 1)
    dj = jnp.where(above, d, 0.0)[..., None, :]           # (..., 1, V)
    denom = di - dj
    t = di / jnp.where(denom == 0, 1.0, denom)            # (..., V, V)
    yi = y[..., :, None]
    yj = y[..., None, :]
    yp = yi + t * (yj - yi)
    pair = below[..., :, None] & above[..., None, :]
    y_pair_lo = jnp.where(pair, yp, big)
    y_pair_hi = jnp.where(pair, yp, -big)

    lo = jnp.minimum(jnp.min(y_on_lo, axis=-1),
                     jnp.min(y_pair_lo, axis=(-2, -1)))
    hi = jnp.maximum(jnp.max(y_on_hi, axis=-1),
                     jnp.max(y_pair_hi, axis=(-2, -1)))
    hit = jnp.any(on, axis=-1) | (jnp.any(below, axis=-1)
                                  & jnp.any(above, axis=-1))
    return lo, hi, hit


@functools.partial(jax.jit, static_argnames=("k",))
def slice_batch(verts: jax.Array, valid: jax.Array, planes: jax.Array,
                k: int) -> tuple[jax.Array, jax.Array]:
    p, v, d = verts.shape
    c = planes[:, None]                              # (P, 1)
    coord = verts[:, :, k]                           # (P, V)
    scale = jnp.maximum(1.0, jnp.max(jnp.abs(coord), axis=1, keepdims=True))
    dist = jnp.where(valid, coord - c, jnp.inf)      # (P, V)

    on = (jnp.abs(dist) <= PLANE_TOL * scale) & valid
    below = (dist < -PLANE_TOL * scale) & valid
    above = (dist > PLANE_TOL * scale) & jnp.isfinite(dist) & valid

    # on-plane vertices, coordinate k snapped onto the plane
    on_pts = verts.at[:, :, k].set(jnp.broadcast_to(c, (p, v)))

    # all-pairs interpolation i(below) -> j(above)
    di = jnp.where(below, dist, 0.0)[:, :, None]         # (P, V, 1)
    dj = jnp.where(above, dist, 0.0)[:, None, :]         # (P, 1, V)
    denom = di - dj
    t = jnp.where(jnp.abs(denom) > 0, di / jnp.where(denom == 0, 1.0, denom),
                  0.0)                                   # (P, V, V)
    vi = verts[:, :, None, :]                            # (P, V, 1, D)
    vj = verts[:, None, :, :]                            # (P, 1, V, D)
    interp = vi + t[..., None] * (vj - vi)               # (P, V, V, D)
    interp = interp.at[:, :, :, k].set(jnp.broadcast_to(c[:, :, None],
                                                        (p, v, v)))
    pair_valid = below[:, :, None] & above[:, None, :]   # (P, V, V)

    out = jnp.concatenate([on_pts, interp.reshape(p, v * v, d)], axis=1)
    out_valid = jnp.concatenate([on, pair_valid.reshape(p, v * v)], axis=1)
    out = jnp.where(out_valid[..., None], out, 0.0)
    return out, out_valid
