"""Jit'd wrapper + host adapters for the batched slice kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import kernel, ref


def slice_batch(verts, valid, planes, k: int, use_pallas: bool = False,
                interpret: bool = True):
    if use_pallas:
        return kernel.slice_batch(verts, valid, planes, k,
                                  interpret=interpret)
    return ref.slice_batch(verts, valid, planes, k)


def pack_polytopes(polys, v_max: int | None = None):
    """Pack a BFS layer of host Polytopes into padded device arrays."""
    if not polys:
        raise ValueError("empty layer")
    d = polys[0].points.shape[1]
    v_max = v_max or max(p.n_vertices for p in polys)
    p = len(polys)
    verts = np.zeros((p, v_max, d), np.float32)
    valid = np.zeros((p, v_max), bool)
    for i, poly in enumerate(polys):
        n = min(poly.n_vertices, v_max)
        verts[i, :n] = poly.points[:n]
        valid[i, :n] = True
    return jnp.asarray(verts), jnp.asarray(valid)


def unpack_sliced(out, mask, axes, k: int):
    """Rebuild host Polytopes from kernel output (drops sliced axis k)."""
    from repro.core.geometry import Polytope, _dedupe
    from repro.core.hull import convex_hull_prune

    out = np.asarray(out, np.float64)
    mask = np.asarray(mask)
    rest = tuple(a for j, a in enumerate(axes) if j != k)
    keep_cols = [j for j in range(out.shape[2]) if j != k]
    polys = []
    for i in range(out.shape[0]):
        pts = out[i][mask[i]][:, keep_cols]
        if len(pts) == 0:
            polys.append(None)
            continue
        pts = convex_hull_prune(_dedupe(pts))
        polys.append(Polytope(rest, pts))
    return polys
