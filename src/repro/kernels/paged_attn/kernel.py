"""Pallas TPU kernel: paged decode attention with online softmax.

The serving engine's Polytope planner (``repro.serve.kv_cache``) emits a
block table — the extraction plan over the KV-cache datacube
(layer, page, slot).  This kernel consumes that plan with scalar
prefetch: grid step (b, kvh, p) DMAs exactly page ``block_table[b, p]``
for kv head ``kvh`` HBM→VMEM and folds it into a running
flash-attention accumulator (m, l, acc held in VMEM scratch).  Pages not
in the plan are never read — the paper's exact-byte I/O on the KV cache.

Decode attention is memory-bound (one q token vs S cached tokens), so
roofline here is HBM bytes = exactly the live pages; a bounding-box
reader would stream the whole padded (B, PMAX·PS) rectangle including
dead pages.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._casting import checked_cast_i32

NEG_INF = -1e30


def _paged_attn_kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, out_ref,
                       m_ref, l_ref, acc_ref, *, ps: int, pmax: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, Dh)
    k = k_ref[0, 0].astype(jnp.float32)              # (PS, Dh)
    v = v_ref[0, 0].astype(jnp.float32)              # (PS, Dh)
    dh = q.shape[-1]

    seq_len = lens_ref[b]
    base = p * ps
    offs = base + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0)
    slot_live = offs < seq_len                        # (PS,)

    s = (q @ k.T) / jnp.sqrt(jnp.float32(dh))         # (G, PS)
    s = jnp.where(slot_live[None, :], s, NEG_INF)

    m_prev = m_ref[...]                               # (G, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    pexp = jnp.exp(s - m_cur)                         # (G, PS)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + pexp @ v
    m_ref[...] = m_cur

    @pl.when(p == pmax - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = (acc_ref[...] / denom).astype(out_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens,
                           interpret: bool = True):
    """Validate the plan indices host-side, then run the jitted kernel.

    ``block_table`` entries are page ids in [0, n_pages) with ``-1``
    marking unused slots; ``seq_lens`` live KV lengths in
    [0, PMAX·PS].  Both are scalar-prefetch inputs the kernel consumes
    as int32, so the cast goes through the bounds-checked helper
    (offsets past 2³¹ raise instead of truncating); tracers pass
    through.
    """
    n_pages, _, ps, _ = k_pages.shape
    pmax = block_table.shape[1]
    table32 = checked_cast_i32(block_table,
                               what="paged_decode_attention block_table",
                               n_elements=n_pages,
                               allow_negative_one=True)
    lens32 = checked_cast_i32(seq_lens,
                              what="paged_decode_attention seq_lens",
                              n_elements=pmax * ps + 1)
    return _paged_decode_attention(q, k_pages, v_pages, table32, lens32,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens,
                            interpret: bool = True):
    b, h, dh = q.shape
    n_pages, kvh, ps, _ = k_pages.shape
    pmax = block_table.shape[1]
    g = h // kvh
    q4 = q.reshape(b, kvh, g, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, pmax),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b_, k_, p_, tbl, ln: (b_, k_, 0, 0)),
            pl.BlockSpec((1, 1, ps, dh),
                         lambda b_, k_, p_, tbl, ln:
                         (jnp.maximum(tbl[b_, p_], 0), k_, 0, 0)),
            pl.BlockSpec((1, 1, ps, dh),
                         lambda b_, k_, p_, tbl, ln:
                         (jnp.maximum(tbl[b_, p_], 0), k_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b_, k_, p_, tbl, ln: (b_, k_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_attn_kernel, ps=ps, pmax=pmax),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dh), q.dtype),
        interpret=interpret,
        name="paged_decode_attention",
    )(block_table, seq_lens, q4, k_pages, v_pages)
    return out.reshape(b, h, dh)
