"""Pure-jnp oracle for paged decode attention (GQA).

Layouts:
  q           — (B, H, Dh)        one new token per sequence
  k_pages     — (NP, KVH, PS, Dh) global page pool
  v_pages     — (NP, KVH, PS, Dh)
  block_table — (B, PMAX) int32   page ids per sequence (-1 = unused)
  seq_lens    — (B,)    int32     live KV length per sequence

H = KVH * G (grouped-query attention).  The oracle materialises the
gathered dense cache; the kernel never does — it reads only the pages
the plan names (the paper's exact-byte promise applied to KV cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens):
    b, h, dh = q.shape
    np_, kvh, ps, _ = k_pages.shape
    pmax = block_table.shape[1]
    g = h // kvh

    table = jnp.maximum(block_table, 0)                    # (B, PMAX)
    k = k_pages[table]                                     # (B, PMAX, KVH, PS, Dh)
    v = v_pages[table]
    k = jnp.moveaxis(k, 2, 1).reshape(b, kvh, pmax * ps, dh)
    v = jnp.moveaxis(v, 2, 1).reshape(b, kvh, pmax * ps, dh)

    pos = jnp.arange(pmax * ps)[None, :]                   # (1, S)
    mask = pos < seq_lens[:, None]                         # (B, S)

    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg,
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)
