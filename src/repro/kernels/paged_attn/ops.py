"""Jit'd dispatch for paged decode attention."""

from __future__ import annotations

from . import kernel, ref


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens,
                           use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return kernel.paged_decode_attention(q, k_pages, v_pages,
                                             block_table, seq_lens,
                                             interpret=interpret)
    return ref.paged_decode_attention(q, k_pages, v_pages, block_table,
                                      seq_lens)
