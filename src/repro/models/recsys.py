"""RecSys architectures: DLRM, DeepFM, two-tower retrieval, BERT4Rec.

The embedding lookup is the hot path (kernel taxonomy §RecSys) and it
*is* the paper's algorithm: a categorical-axis extraction on the
(row, dim) table datacube — plan the rows, read only those bytes.
``EmbeddingBag`` below is exactly ``repro.kernels.gather.gather_rows_bag``
semantics (take + segment-sum with -1 padding); tables shard row-wise
over the mesh's ``model`` axis.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from .layers import cross_entropy, embedding_init, mlp, mlp_init
from .transformer import TransformerConfig, forward as tf_forward, \
    init_params as tf_init

Params = Any


# ---------------------------------------------------------------------------
# EmbeddingBag — the extraction engine's categorical path as an NN module
# ---------------------------------------------------------------------------
def embedding_bag_init(key, n_tables: int, rows: int, dim: int,
                       dtype=jnp.float32) -> Params:
    """One stacked table tensor (T, rows, dim) — row-sharded over `model`."""
    scale = 1.0 / math.sqrt(dim)
    return {"tables": (jax.random.normal(key, (n_tables, rows, dim))
                       * scale).astype(dtype)}


def embedding_bag(params: Params, bags: jax.Array,
                  combine: str = "sum") -> jax.Array:
    """bags (B, T, L) int32 with -1 padding → (B, T, dim).

    take + masked segment-sum over the bag axis — JAX has no native
    EmbeddingBag; this IS the system's implementation (and matches the
    Pallas ``gather_rows_bag`` kernel bit-for-bit).
    """
    tables = params["tables"]                 # (T, R, D)
    valid = (bags >= 0)
    idx = jnp.maximum(bags, 0)
    # per-table gather: rows[b, t, l, d] = tables[t, bags[b,t,l], d]
    rows = _gather_tables(tables, idx)
    rows = jnp.where(valid[..., None], rows, 0)
    out = jnp.sum(rows, axis=2)
    if combine == "mean":
        out = out / jnp.maximum(jnp.sum(valid, axis=2), 1)[..., None]
    return out


def _gather_tables(tables: jax.Array, idx: jax.Array) -> jax.Array:
    """tables (T,R,D), idx (B,T,L) → (B,T,L,D) via per-table take."""
    def one(table, ids):                      # (R,D), (B,L)
        return jnp.take(table, ids, axis=0)   # (B,L,D)

    out = jax.vmap(one, in_axes=(0, 1), out_axes=1)(tables,
                                                    idx)  # (B,T,L,D)
    return out


# ---------------------------------------------------------------------------
# DLRM (RM-2)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    rows: int = 1_000_000
    embed_dim: int = 64
    bot_mlp: tuple[int, ...] = (512, 256, 64)
    top_mlp: tuple[int, ...] = (512, 512, 256, 1)
    bag_size: int = 1
    dtype: Any = jnp.float32


def dlrm_init(key, cfg: DLRMConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "bags": embedding_bag_init(k1, cfg.n_sparse, cfg.rows,
                                   cfg.embed_dim, cfg.dtype),
        "bot": mlp_init(k2, [cfg.n_dense, *cfg.bot_mlp], cfg.dtype),
        "top": mlp_init(k3, [cfg.embed_dim +
                             (cfg.n_sparse + 1) * cfg.n_sparse // 2,
                             *cfg.top_mlp], cfg.dtype),
    }


def dlrm_forward(params: Params, cfg: DLRMConfig, dense: jax.Array,
                 bags: jax.Array) -> jax.Array:
    """dense (B, n_dense), bags (B, n_sparse, L) → logits (B,)."""
    d = mlp(params["bot"], dense.astype(cfg.dtype))        # (B, D)
    e = embedding_bag(params["bags"], bags)                # (B, T, D)
    z = jnp.concatenate([d[:, None, :], e], axis=1)        # (B, T+1, D)
    inter = jnp.einsum("bid,bjd->bij", z, z)               # dot interaction
    iu, ju = jnp.triu_indices(z.shape[1], k=1)
    flat = inter[:, iu, ju]                                # (B, pairs)
    x = jnp.concatenate([d, flat], axis=1)
    return mlp(params["top"], x)[:, 0]


def dlrm_loss(params: Params, cfg: DLRMConfig, batch: dict) -> jax.Array:
    logits = dlrm_forward(params, cfg, batch["dense"], batch["bags"])
    return _bce(logits, batch["labels"])


# ---------------------------------------------------------------------------
# DeepFM
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_sparse: int = 39
    rows: int = 1_000_000
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    dtype: Any = jnp.float32


def deepfm_init(key, cfg: DeepFMConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "bags": embedding_bag_init(k1, cfg.n_sparse, cfg.rows,
                                   cfg.embed_dim, cfg.dtype),
        "linear": embedding_bag_init(k2, cfg.n_sparse, cfg.rows, 1,
                                     cfg.dtype),
        "deep": mlp_init(k3, [cfg.n_sparse * cfg.embed_dim,
                              *cfg.mlp_dims, 1], cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
    }


def deepfm_forward(params: Params, cfg: DeepFMConfig,
                   bags: jax.Array) -> jax.Array:
    """bags (B, n_sparse, L) → logits (B,)."""
    v = embedding_bag(params["bags"], bags)                # (B, F, D)
    lin = embedding_bag(params["linear"], bags)[..., 0]    # (B, F)
    # FM second-order: ½[(Σv)² − Σv²]
    s = jnp.sum(v, axis=1)
    fm = 0.5 * jnp.sum(jnp.square(s) - jnp.sum(jnp.square(v), axis=1),
                       axis=-1)
    deep = mlp(params["deep"], v.reshape(v.shape[0], -1))[:, 0]
    return params["bias"] + jnp.sum(lin, axis=1) + fm + deep


def deepfm_loss(params: Params, cfg: DeepFMConfig, batch: dict) -> jax.Array:
    return _bce(deepfm_forward(params, cfg, batch["bags"]),
                batch["labels"])


# ---------------------------------------------------------------------------
# Two-tower retrieval
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_users: int = 1_000_000
    n_items: int = 1_000_000
    embed_dim: int = 256
    tower: tuple[int, ...] = (1024, 512, 256)
    temperature: float = 0.05
    dtype: Any = jnp.float32


def twotower_init(key, cfg: TwoTowerConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "user_embed": embedding_init(k1, cfg.n_users, cfg.embed_dim,
                                     cfg.dtype),
        "item_embed": embedding_init(k2, cfg.n_items, cfg.embed_dim,
                                     cfg.dtype),
        "user_tower": mlp_init(k3, [cfg.embed_dim, *cfg.tower], cfg.dtype),
        "item_tower": mlp_init(k4, [cfg.embed_dim, *cfg.tower], cfg.dtype),
    }


def _l2n(x):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def twotower_user(params, cfg, user_ids):
    u = jnp.take(params["user_embed"]["table"], user_ids, axis=0)
    return _l2n(mlp(params["user_tower"], u.astype(cfg.dtype)))


def twotower_item(params, cfg, item_ids):
    i = jnp.take(params["item_embed"]["table"], item_ids, axis=0)
    return _l2n(mlp(params["item_tower"], i.astype(cfg.dtype)))


def twotower_loss(params: Params, cfg: TwoTowerConfig,
                  batch: dict) -> jax.Array:
    """In-batch sampled softmax with logQ correction [Yi et al. '19]."""
    u = twotower_user(params, cfg, batch["user_ids"])      # (B, D)
    i = twotower_item(params, cfg, batch["item_ids"])      # (B, D)
    logits = (u @ i.T) / cfg.temperature                   # (B, B)
    logq = batch.get("item_logq")
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    return cross_entropy(logits, labels)


def twotower_score_candidates(params: Params, cfg: TwoTowerConfig,
                              user_ids: jax.Array,
                              cand_item_ids: jax.Array) -> jax.Array:
    """retrieval_cand shape: one query × 10⁶ candidates = one sharded
    matvec (no loop)."""
    u = twotower_user(params, cfg, user_ids)               # (B, D)
    c = twotower_item(params, cfg, cand_item_ids)          # (N, D)
    return u @ c.T                                         # (B, N)


# ---------------------------------------------------------------------------
# BERT4Rec — bidirectional transformer over item sequences
# ---------------------------------------------------------------------------
def bert4rec_config(n_items: int = 50_000, seq_len: int = 200,
                    dtype=jnp.float32) -> TransformerConfig:
    return TransformerConfig(
        name="bert4rec", vocab=n_items + 2,     # +mask, +pad tokens
        d_model=64, n_layers=2, n_heads=2, n_kv_heads=2, d_head=32,
        d_ff=256, causal=False, learned_pos=True, max_seq=seq_len,
        dtype=dtype, q_chunk=None)


def bert4rec_init(key, cfg: TransformerConfig) -> Params:
    return tf_init(key, cfg)


MAX_MASKED = 48   # cloze positions kept per sequence (0.2 × 200 + slack)


def bert4rec_loss(params: Params, cfg: TransformerConfig,
                  batch: dict) -> jax.Array:
    """Masked-item prediction (cloze) over the item vocabulary.

    §Perf: the paper-faithful formulation materialises (B, S, V) logits
    — 3.8 TB at the assigned train_batch.  The exact-bytes fix computes
    hidden states once, *gathers only the masked positions* (≤ 48 of
    200) and runs an online-logsumexp CE over vocabulary chunks, never
    materialising the (…, 2²⁰) logit tensor.
    """
    from repro.models.layers import cross_entropy_tied_chunked
    from repro.models.transformer import trunk

    h, _ = trunk(params, cfg, batch["items"])            # (B, S, D)
    mask = batch["mask"]
    # top-MAX_MASKED masked positions per row (ties broken by position)
    order = jnp.argsort(-mask, axis=1, stable=True)[:, :MAX_MASKED]
    h_m = jnp.take_along_axis(h, order[..., None], axis=1)
    lab_m = jnp.take_along_axis(batch["labels"], order, axis=1)
    w_m = jnp.take_along_axis(mask, order, axis=1)
    return cross_entropy_tied_chunked(
        h_m, params["embed"]["table"], lab_m, w_m, chunk=4096)


def bert4rec_score(params: Params, cfg: TransformerConfig,
                   items: jax.Array) -> jax.Array:
    """Next-item scores at the last position (serving).

    §Perf: unembed only the final position — (B, V) instead of
    (B, S, V), a 200× cut in serve_bulk's memory term."""
    from repro.models.layers import unembed
    from repro.models.transformer import trunk

    h, _ = trunk(params, cfg, items)
    return unembed(params["embed"], h[:, -1])


def _bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
