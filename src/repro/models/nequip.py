"""NequIP — E(3)-equivariant message passing [arXiv:2101.03164].

Irrep regime (kernel taxonomy §GNN: "irrep tensor-product"): node
features are per-l real-spherical-harmonic channels {l: (N, C, 2l+1)},
messages are channel-wise tensor products of neighbour features with
Y_l(r̂_ij), contracted through **Gaunt coefficient** tensors
G[m1,m2,m3] = ∫ Y_{l1 m1} Y_{l2 m2} Y_{l3 m3} dΩ — the real-SH analogue
of Clebsch-Gordan coupling.  G is computed *numerically exactly* at
module-build time with Gauss–Legendre × uniform-φ quadrature (the
integrand is band-limited, so the quadrature is exact), avoiding
hand-copied CG tables.

Message passing is ``segment_sum`` over the edge list — JAX has no
sparse message-passing primitive, so the scatter IS part of the system
(and maps to the one-hot-MXU kernel in ``repro.kernels.segment``).

The same trunk serves all four assigned graph shapes: node
classification (Cora / ogbn-products style, synthetic positions) and
per-graph energies (+ optional conservative forces via ``-∂E/∂pos``)
for batched molecules.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.context import constrain

from .layers import mlp, mlp_init

Params = Any

# nodes/edges shard over every mesh axis (256-way on the single pod)
GRAPH_AXES = ("pod", "data", "model")


# ---------------------------------------------------------------------------
# real spherical harmonics (l <= 2), unit vectors
# ---------------------------------------------------------------------------
def sph_harm_np(l: int, v: np.ndarray) -> np.ndarray:
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return np.full(v.shape[:-1] + (1,), 0.2820947917738781)
    if l == 1:
        c = 0.4886025119029199
        return np.stack([c * y, c * z, c * x], -1)
    if l == 2:
        c1, c2, c3 = 1.0925484305920792, 0.31539156525252005, \
            0.5462742152960396
        return np.stack([c1 * x * y, c1 * y * z,
                         c2 * (3 * z ** 2 - 1.0),
                         c1 * x * z, c3 * (x ** 2 - y ** 2)], -1)
    raise NotImplementedError(l)


def sph_harm(l: int, v: jax.Array) -> jax.Array:
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return jnp.full(v.shape[:-1] + (1,), 0.2820947917738781,
                        dtype=v.dtype)
    if l == 1:
        c = 0.4886025119029199
        return jnp.stack([c * y, c * z, c * x], -1)
    if l == 2:
        c1, c2, c3 = 1.0925484305920792, 0.31539156525252005, \
            0.5462742152960396
        return jnp.stack([c1 * x * y, c1 * y * z,
                          c2 * (3 * z ** 2 - 1.0),
                          c1 * x * z, c3 * (x ** 2 - y ** 2)], -1)
    raise NotImplementedError(l)


@functools.lru_cache(maxsize=None)
def gaunt(l1: int, l2: int, l3: int) -> np.ndarray:
    """G[m1, m2, m3] = ∫ Y_{l1m1} Y_{l2m2} Y_{l3m3} dΩ (exact quadrature).

    Gauss–Legendre (cosθ, order 24) × uniform φ (64 nodes) integrates
    band-limited spherical polynomials of total degree ≤ 6 exactly.
    """
    nodes, weights = np.polynomial.legendre.leggauss(24)
    phi = 2 * np.pi * (np.arange(64) + 0.5) / 64
    ct, ph = np.meshgrid(nodes, phi, indexing="ij")       # (24, 64)
    st = np.sqrt(1 - ct ** 2)
    v = np.stack([st * np.cos(ph), st * np.sin(ph), ct], -1)
    w = np.broadcast_to(weights[:, None] * (2 * np.pi / 64),
                        (24, 64)).ravel()
    v = v.reshape(-1, 3)
    y1, y2, y3 = (sph_harm_np(l, v) for l in (l1, l2, l3))
    g = np.einsum("q,qa,qb,qc->abc", w, y1, y2, y3)
    g[np.abs(g) < 1e-12] = 0.0
    return g.astype(np.float32)


def tp_paths(l_max: int) -> list[tuple[int, int, int]]:
    """All (l_in, l_filter, l_out) with non-vanishing Gaunt coupling."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                if (l1 + l2 + l3) % 2 == 0 and np.abs(
                        gaunt(l1, l2, l3)).max() > 1e-8:
                    out.append((l1, l2, l3))
    return out


# ---------------------------------------------------------------------------
def bessel_rbf(r: jax.Array, n: int, cutoff: float) -> jax.Array:
    """Bessel radial basis [DimeNet] with p=6 polynomial envelope."""
    r = jnp.maximum(r, 1e-6)
    k = jnp.arange(1, n + 1, dtype=r.dtype)
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(k * jnp.pi * r[..., None]
                                          / cutoff) / r[..., None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    p = 6
    env = (1 - (p + 1) * (p + 2) / 2 * x ** p + p * (p + 2) * x ** (p + 1)
           - p * (p + 1) / 2 * x ** (p + 2))
    return rb * env[..., None]


@dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16              # input node feature dim
    n_out: int = 1                # classes or 1 (energy)
    readout: str = "energy"       # "energy" | "node_class"
    radial_hidden: int = 64
    dtype: Any = jnp.float32

    @property
    def ls(self) -> tuple[int, ...]:
        return tuple(range(self.l_max + 1))

    @property
    def paths(self) -> list[tuple[int, int, int]]:
        return tp_paths(self.l_max)


def nequip_init(key, cfg: NequIPConfig) -> Params:
    keys = jax.random.split(key, 3 + cfg.n_layers)
    c = cfg.channels
    params: dict = {
        "embed": mlp_init(keys[0], [cfg.d_feat, c], cfg.dtype),
        "layers": [],
        "readout": mlp_init(keys[1], [c, c, cfg.n_out], cfg.dtype),
    }
    n_paths = len(cfg.paths)
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + i], 4 + 2 * len(cfg.ls))
        layer = {
            # radial MLP → per-(path, channel) weights
            "radial": mlp_init(lk[0], [cfg.n_rbf, cfg.radial_hidden,
                                       n_paths * c], cfg.dtype),
            "self": {}, "mix": {}, "gate": {},
        }
        for j, l in enumerate(cfg.ls):
            n_in_paths = sum(1 for (li, lf, lo) in cfg.paths if lo == l)
            if n_in_paths == 0:
                continue
            layer["mix"][str(l)] = (
                jax.random.normal(lk[4 + 2 * j], (n_in_paths * c, c))
                / math.sqrt(n_in_paths * c)).astype(cfg.dtype)
            layer["self"][str(l)] = (
                jax.random.normal(lk[5 + 2 * j], (c, c)) / math.sqrt(c)
            ).astype(cfg.dtype)
            if l > 0:
                layer["gate"][str(l)] = (
                    jax.random.normal(lk[1], (c, c)) / math.sqrt(c)
                ).astype(cfg.dtype)
        params["layers"].append(layer)
    return params


def _tp_message(feats: dict, ys: dict, radial_w: jax.Array,
                cfg: NequIPConfig, src: jax.Array,
                edge_mask: jax.Array) -> dict:
    """Per-edge tensor-product messages, grouped by output l."""
    c = cfg.channels
    out: dict[int, list] = {l: [] for l in cfg.ls}
    for pi, (li, lf, lo) in enumerate(cfg.paths):
        g = jnp.asarray(gaunt(li, lf, lo))               # (2li+1,2lf+1,2lo+1)
        h_src = feats[li][src]                           # (E, C, 2li+1)
        w = radial_w[:, pi * c:(pi + 1) * c]             # (E, C)
        msg = jnp.einsum("eca,eb,abm->ecm", h_src, ys[lf], g)
        msg = msg * (w * edge_mask[:, None])[..., None]
        out[lo].append(msg)
    return {l: jnp.concatenate(v, axis=1) for l, v in out.items() if v}


def nequip_forward(params: Params, cfg: NequIPConfig, node_feat: jax.Array,
                   positions: jax.Array, edge_index: jax.Array,
                   node_mask: jax.Array | None = None,
                   graph_ids: jax.Array | None = None,
                   n_graphs: int = 1):
    """edge_index (2, E) int32 (src, dst); padding edges = -1.

    Returns per-node outputs (N, n_out) for ``node_class`` or per-graph
    energies (n_graphs,) for ``energy``.
    """
    n = node_feat.shape[0]
    c = cfg.channels
    src, dst = edge_index[0], edge_index[1]
    edge_mask = (src >= 0) & (dst >= 0)
    srcc = jnp.maximum(src, 0)
    dstc = jnp.maximum(dst, 0)

    rel = positions[srcc] - positions[dstc]              # (E, 3)
    r = jnp.linalg.norm(rel + 1e-12, axis=-1)
    rhat = rel / jnp.maximum(r, 1e-6)[:, None]
    ys = {l: sph_harm(l, rhat).astype(cfg.dtype) for l in cfg.ls}
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    emask = (edge_mask & (r <= cfg.cutoff)).astype(cfg.dtype)

    feats = {l: jnp.zeros((n, c, 2 * l + 1), cfg.dtype) for l in cfg.ls}
    feats[0] = mlp(params["embed"], node_feat.astype(cfg.dtype))[..., None]

    def apply_layer(layer, feats):
        radial_w = mlp(layer["radial"], rbf)             # (E, paths*C)
        msgs = _tp_message(feats, ys, radial_w, cfg, srcc, emask)
        new_feats = {}
        for l in cfg.ls:
            if l not in msgs:
                new_feats[l] = feats[l]
                continue
            # §Perf iteration 7: the channel mix is linear, so it
            # commutes with the (linear) scatter-add — apply it on the
            # *edge* messages (local, edge-sharded) before aggregating.
            # The scatter buffer and its all-reduce shrink from
            # (N, paths·C, M) to (N, C, M): 4× less for l>0 on
            # ogb_products.
            msg = constrain(msgs[l], GRAPH_AXES, None, None)
            msg_mixed = jnp.einsum("epm,pc->ecm", msg,
                                   layer["mix"][str(l)])
            mixed = jax.ops.segment_sum(msg_mixed, dstc, num_segments=n)
            mixed = constrain(mixed, GRAPH_AXES, None, None)
            self_c = jnp.einsum("ncm,cd->ndm", feats[l],
                                layer["self"][str(l)])
            h = mixed + self_c
            if l == 0:
                h = jax.nn.silu(h)
            else:
                gate = jax.nn.sigmoid(
                    jnp.einsum("nc,cd->nd", feats[0][..., 0],
                               layer["gate"][str(l)]))
                h = h * gate[..., None]
            new_feats[l] = constrain(h, GRAPH_AXES, None, None)
        return new_feats

    # remat per layer: the (E, paths·C, 2l+1) message tensors are the
    # memory hot spot on 60M-edge graphs — recompute them in backward.
    for layer in params["layers"]:
        feats = jax.checkpoint(apply_layer)(layer, feats)

    scalars = feats[0][..., 0]                           # (N, C)
    out = mlp(params["readout"], scalars)                # (N, n_out)
    if node_mask is not None:
        out = out * node_mask[:, None]
    if cfg.readout == "node_class":
        return out
    gid = graph_ids if graph_ids is not None else jnp.zeros((n,), jnp.int32)
    return jax.ops.segment_sum(out[:, 0], gid, num_segments=n_graphs)


def nequip_energy_forces(params: Params, cfg: NequIPConfig, node_feat,
                         positions, edge_index, node_mask=None,
                         graph_ids=None, n_graphs: int = 1):
    """Conservative forces F = -∂E/∂positions."""
    def etot(pos):
        e = nequip_forward(params, cfg, node_feat, pos, edge_index,
                           node_mask, graph_ids, n_graphs)
        return jnp.sum(e), e

    (_, e), neg_f = jax.value_and_grad(etot, has_aux=True)(positions)
    return e, -neg_f


def nequip_loss(params: Params, cfg: NequIPConfig, batch: dict):
    if cfg.readout == "node_class":
        logits = nequip_forward(params, cfg, batch["node_feat"],
                                batch["positions"], batch["edge_index"],
                                batch.get("node_mask"))
        labels = batch["labels"]
        mask = batch.get("label_mask")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        if mask is not None:
            return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.mean(nll)
    if batch.get("forces") is not None:
        e, f = nequip_energy_forces(params, cfg, batch["node_feat"],
                                    batch["positions"],
                                    batch["edge_index"],
                                    batch.get("node_mask"),
                                    batch.get("graph_ids"),
                                    batch.get("n_graphs", 1))
        el = jnp.mean(jnp.square(e - batch["energy"]))
        fl = jnp.mean(jnp.square(f - batch["forces"]))
        return el + 100.0 * fl
    e = nequip_forward(params, cfg, batch["node_feat"], batch["positions"],
                       batch["edge_index"], batch.get("node_mask"),
                       batch.get("graph_ids"), batch.get("n_graphs", 1))
    return jnp.mean(jnp.square(e - batch["energy"]))
