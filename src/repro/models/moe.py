"""Mixture-of-Experts FFN (DeepSeek-V3 / Arctic style).

Routing: top-k softmax gates + GShard capacity dispatch.  The dispatch
is scatter/gather based (position-in-expert via cumsum over the token
axis), *not* the one-hot einsum formulation — the einsum dispatch costs
O(T·E·C·D) FLOPs and would swamp the roofline's compute term with
routing overhead; scatter keeps dispatch O(T·k·D).

Expert parallelism: expert-major weight tensors (E, D, F) shard E over
the mesh's ``model`` axis (16 experts/shard for DeepSeek-V3 on a 16-way
axis).  Activations enter replicated across ``model``; GSPMD partitions
the grouped GEMM over E and all-reduces the combine — the paper-faithful
baseline.  (Hillclimb: shard_map all-to-all dispatch, see EXPERIMENTS
§Perf.)

Aux losses: switch load-balance loss + router z-loss.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain

from .layers import dense_init

Params = Any


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                     # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0             # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 1e-4
    # GShard grouping: routing/capacity are computed per group so the
    # dispatch buffers (G, E, C, D) shard over (data, model) instead of
    # materialising a global (E, C_global, D).  Must divide B·S.
    n_groups: int = 1


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(kr, d, e, jnp.float32)["w"],
        "w_gate": (jax.random.normal(k1, (e, d, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(k2, (e, d, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(k3, (e, f, d)) /
                   math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = {
            "w_gate": (jax.random.normal(ks, (d, cfg.n_shared * f)) * s
                       ).astype(dtype),
            "w_up": (jax.random.normal(k1, (d, cfg.n_shared * f)) * s
                     ).astype(dtype),
            "w_down": (jax.random.normal(k2, (cfg.n_shared * f, d)) /
                       math.sqrt(f)).astype(dtype),
        }
    return p


def moe_ffn(params: Params, cfg: MoEConfig, x: jax.Array,
            dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) → (out, aux_loss).

    ``dropless=True`` sizes each expert buffer to hold every token
    (capacity = T) — used on the decode path, where T = batch is tiny
    and token dropping would perturb generation."""
    b, s, d = x.shape
    t = b * s
    g = min(cfg.n_groups, t)
    if t % g:
        g = 1
    xg = x.reshape(g, t // g, d)
    out, aux = jax.vmap(
        lambda xt: _moe_group(params, cfg, xt, dropless))(xg)
    return out.reshape(b, s, d), jnp.mean(aux)


def _moe_group(params: Params, cfg: MoEConfig, xt: jax.Array,
               dropless: bool) -> tuple[jax.Array, jax.Array]:
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = (xt.astype(jnp.float32) @ params["router"])     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    capacity = t if dropless else max(1, int(cfg.capacity_factor * t * k
                                             / e))

    # position of each (token, choice) within its expert buffer
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - 1)           # (T·k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1)             # (T·k,)
    eid = expert_ids.reshape(t * k)
    keep = pos < capacity                                    # drop overflow

    # dispatch: expert_in[e, c] = x[token routed to (e, c)]
    xk = jnp.repeat(xt, k, axis=0)                           # (T·k, D)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    expert_in = jnp.zeros((e, capacity, d), xt.dtype)
    expert_in = expert_in.at[eid, safe_pos].add(
        jnp.where(keep[:, None], xk, 0).astype(xt.dtype))
    # Dispatch stays local to the token's data shard: E is *replicated*
    # here.  (§Perf iteration 2 tried E-sharding this buffer — SPMD
    # answered with a bigger forward all-gather; refuted, see
    # EXPERIMENTS.md.)  The grouped GEMM slices E locally from the
    # model-sharded weights; the combine is the scatter-add above.
    expert_in = constrain(expert_in, None, None, None)

    # grouped GEMM over experts (E sharded over `model`)
    h = jnp.einsum("ecd,edf->ecf", expert_in,
                   params["w_gate"].astype(xt.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in,
                   params["w_up"].astype(xt.dtype))
    h = jax.nn.silu(h) * u
    expert_out = jnp.einsum("ecf,efd->ecd", h,
                            params["w_down"].astype(xt.dtype))

    # Combine as a scatter-add (EXPERIMENTS.md §Perf iteration 1).
    # A gather `expert_out[eid, pos]` would force SPMD to replicate the
    # (E, C, D) buffer — an 18.8 GB all-gather per DeepSeek layer.  The
    # scatter formulation keeps expert_out E-sharded: SPMD lowers it to
    # local-scatter + all-reduce of the (T, D) output (the embedding-
    # gradient pattern), moving T·D bytes instead of E·C·D.
    gates = gate_vals.astype(jnp.float32).reshape(t * k)
    eid_safe = jnp.where(keep, eid, e)        # dropped slots → OOB → drop
    gate_slot = jnp.zeros((e, capacity), jnp.float32)
    gate_slot = gate_slot.at[eid_safe, safe_pos].add(gates, mode="drop")
    tok_of_slot = jnp.full((e, capacity), t, jnp.int32)      # t = dummy
    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    tok_of_slot = tok_of_slot.at[eid_safe, safe_pos].set(
        tok.astype(jnp.int32), mode="drop")
    weighted = expert_out * gate_slot[..., None].astype(xt.dtype)
    out = jnp.zeros((t + 1, d), xt.dtype)
    out = out.at[tok_of_slot.reshape(-1)].add(
        weighted.reshape(e * capacity, d), mode="drop")
    out = out[:t]

    if cfg.n_shared:
        sh = params["shared"]
        g = xt @ sh["w_gate"].astype(xt.dtype)
        uu = xt @ sh["w_up"].astype(xt.dtype)
        out = out + (jax.nn.silu(g) * uu) @ sh["w_down"].astype(xt.dtype)

    # aux losses (f32)
    density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(density * router_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = cfg.aux_loss_weight * lb_loss + cfg.z_loss_weight * z_loss
    return out, aux
