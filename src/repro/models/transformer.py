"""Composable transformer covering all five assigned LM architectures.

One config describes GQA (GLM-4 / Yi / Granite), MLA + fine-grained MoE
+ MTP (DeepSeek-V3) and dense-residual MoE (Arctic).  Layers are
*stacked per group* and executed with ``jax.lax.scan`` + ``jax.checkpoint``
so the lowered HLO is depth-independent (61-layer DeepSeek compiles as
fast as 2-layer smoke configs) and activation memory stays one-layer.

Groups: a leading dense-FFN group (DeepSeek's first 3 layers) followed
by the MoE group; pure-dense models have a single group.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import DP, constrain

from .attention import (AttnConfig, gqa_decode, gqa_forward, gqa_init,
                        mla_decode, mla_forward, mla_init)
from .layers import (cross_entropy, dense_init, embed, embedding_init,
                     glu_ffn, glu_ffn_init, rmsnorm, rmsnorm_init, unembed)
from .moe import MoEConfig, moe_ffn, moe_init

Params = Any


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    # attention
    attn_type: str = "gqa"                  # "gqa" | "mla"
    q_lora_rank: int | None = None
    kv_lora_rank: int | None = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10_000.0
    causal: bool = True
    learned_pos: bool = False               # BERT4Rec-style
    max_seq: int = 8192                     # for learned positions only
    # ffn
    moe: MoEConfig | None = None
    n_dense_layers: int = 0                 # leading dense layers w/ MoE
    dense_d_ff: int | None = None           # d_ff of those dense layers
    dense_residual: bool = False            # Arctic: dense FFN ∥ MoE
    # heads
    mtp: bool = False                       # DeepSeek multi-token predict
    mtp_loss_weight: float = 0.3
    tied_embeddings: bool = True
    # execution
    dtype: Any = jnp.float32
    q_chunk: int | None = 1024
    remat: bool = True
    # Fully unroll the layer scans.  Used by the dry-run's cost probes:
    # XLA's cost_analysis counts while-loop bodies once, so per-layer
    # FLOPs are measured on small unrolled configs and extrapolated.
    scan_unroll: bool = False

    def attn_config(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.d_head,
            rope_theta=self.rope_theta, q_lora_rank=self.q_lora_rank,
            kv_lora_rank=self.kv_lora_rank, qk_nope_dim=self.qk_nope_dim,
            qk_rope_dim=self.qk_rope_dim, v_head_dim=self.v_head_dim)

    def layer_groups(self) -> list[tuple[int, bool]]:
        """[(n_layers, uses_moe), …] in execution order."""
        if self.moe is None:
            return [(self.n_layers, False)]
        if self.n_dense_layers:
            return [(self.n_dense_layers, False),
                    (self.n_layers - self.n_dense_layers, True)]
        return [(self.n_layers, True)]


# -- init --------------------------------------------------------------------
def _layer_init(key, cfg: TransformerConfig, use_moe: bool) -> Params:
    ka, kf, ks = jax.random.split(key, 3)
    acfg = cfg.attn_config()
    attn = (mla_init(ka, acfg, cfg.dtype) if cfg.attn_type == "mla"
            else gqa_init(ka, acfg, cfg.dtype))
    p = {
        "attn_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn,
        "ffn_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if use_moe:
        p["moe"] = moe_init(kf, cfg.moe, cfg.dtype)
        if cfg.dense_residual:
            p["ffn"] = glu_ffn_init(ks, cfg.d_model,
                                    cfg.dense_d_ff or cfg.d_ff, cfg.dtype)
    else:
        d_ff = cfg.dense_d_ff if (cfg.moe is not None and cfg.dense_d_ff)\
            else cfg.d_ff
        p["ffn"] = glu_ffn_init(kf, cfg.d_model, d_ff, cfg.dtype)
    return p


def init_params(key, cfg: TransformerConfig) -> Params:
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": embedding_init(keys[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "groups": [],
    }
    if cfg.learned_pos:
        params["pos_embed"] = embedding_init(keys[6], cfg.max_seq,
                                             cfg.d_model, cfg.dtype)
    if not cfg.tied_embeddings:
        params["head"] = dense_init(keys[7], cfg.d_model, cfg.vocab,
                                    cfg.dtype)
    for gi, (n, use_moe) in enumerate(cfg.layer_groups()):
        gkeys = jax.random.split(keys[1 + gi], n)
        stacked = jax.vmap(
            lambda k: _layer_init(k, cfg, use_moe))(gkeys)
        params["groups"].append(stacked)
    if cfg.mtp:
        km = jax.random.split(keys[5], 3)
        params["mtp"] = {
            "norm_h": rmsnorm_init(cfg.d_model, cfg.dtype),
            "norm_e": rmsnorm_init(cfg.d_model, cfg.dtype),
            "proj": dense_init(km[0], 2 * cfg.d_model, cfg.d_model,
                               cfg.dtype),
            "layer": _layer_init(km[1], cfg, use_moe=False),
        }
    return params


# -- forward -------------------------------------------------------------
def _layer_apply(cfg: TransformerConfig, use_moe: bool, lp: Params,
                 x: jax.Array, positions: jax.Array,
                 q_chunk: int | None):
    acfg = cfg.attn_config()
    # Batch stays on the data axes; embedding gathers and microbatch
    # reshapes otherwise leak replicated activations into the stack.
    x = constrain(x, DP, None, None)
    h = rmsnorm(lp["attn_norm"], x)
    fwd = mla_forward if cfg.attn_type == "mla" else gqa_forward
    h = fwd(lp["attn"], acfg, h, positions, causal=cfg.causal,
            q_chunk=q_chunk, unroll=cfg.scan_unroll)
    x = x + h
    f = rmsnorm(lp["ffn_norm"], x)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        out, aux = moe_ffn(lp["moe"], cfg.moe, f)
        if cfg.dense_residual:
            out = out + glu_ffn(lp["ffn"], f)
    else:
        out = glu_ffn(lp["ffn"], f)
    return x + out, aux


def trunk(params: Params, cfg: TransformerConfig, tokens: jax.Array,
          positions: jax.Array | None = None
          ) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) → (hidden (B, S, D) after final norm, aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed(params["embed"], tokens).astype(cfg.dtype)
    if cfg.learned_pos:
        x = x + embed(params["pos_embed"], positions).astype(cfg.dtype)
    x = constrain(x, DP, None, None)

    aux_total = jnp.zeros((), jnp.float32)
    for gp, (n, use_moe) in zip(params["groups"], cfg.layer_groups()):
        def body(carry, lp):
            x, aux = carry
            fn = lambda p_, x_: _layer_apply(cfg, use_moe, p_, x_,
                                             positions, cfg.q_chunk)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x2, a = fn(lp, x)
            return (x2, aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), gp,
                                         unroll=cfg.scan_unroll)

    return rmsnorm(params["final_norm"], x), aux_total


def forward(params: Params, cfg: TransformerConfig, tokens: jax.Array,
            positions: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) → (logits (B, S, V), aux_loss)."""
    h, aux_total = trunk(params, cfg, tokens, positions)
    logits = (unembed(params["embed"], h) if cfg.tied_embeddings
              else h @ params["head"]["w"].astype(h.dtype))
    return logits, aux_total


def loss_fn(params: Params, cfg: TransformerConfig, tokens: jax.Array,
            labels: jax.Array,
            mask: jax.Array | None = None) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, tokens)
    ce = cross_entropy(logits, labels, mask)
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp:
        mtp_ce = _mtp_loss(params, cfg, tokens, labels)
        loss = loss + cfg.mtp_loss_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics


def _mtp_loss(params: Params, cfg: TransformerConfig, tokens: jax.Array,
              labels: jax.Array) -> jax.Array:
    """DeepSeek-V3 multi-token prediction (depth-1): predict t+2 from
    h_t ⊕ emb(t+1) through one extra layer sharing the embedding/head."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed(params["embed"], tokens).astype(cfg.dtype)
    # trunk features without the head: reuse the first group cheaply by
    # re-embedding — faithful enough at depth 1 MTP: combine shifted emb.
    nxt = jnp.roll(tokens, -1, axis=1)
    mp = params["mtp"]
    hcat = jnp.concatenate([
        rmsnorm(mp["norm_h"], x),
        rmsnorm(mp["norm_e"], embed(params["embed"], nxt).astype(cfg.dtype)),
    ], axis=-1)
    h = hcat @ mp["proj"]["w"].astype(cfg.dtype)
    h, _ = _layer_apply(cfg, False, mp["layer"], h, positions, cfg.q_chunk)
    logits = unembed(params["embed"], rmsnorm(params["final_norm"], h))
    mtp_labels = jnp.roll(labels, -1, axis=1)
    mask = (jnp.arange(s)[None, :] < s - 2).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (b, s))
    return cross_entropy(logits, mtp_labels, mask)


# -- serving -------------------------------------------------------------
def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None) -> Params:
    """Dense decode cache, stacked (L, …) per group for scan."""
    dtype = dtype or cfg.dtype
    caches = []
    for n, _ in cfg.layer_groups():
        if cfg.attn_type == "mla":
            caches.append({
                "c_kv": jnp.zeros((n, batch, max_seq, cfg.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros((n, batch, max_seq, cfg.qk_rope_dim),
                                    dtype),
            })
        else:
            caches.append({
                "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads,
                                cfg.d_head), dtype),
                "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads,
                                cfg.d_head), dtype),
            })
    return caches


def prefill(params: Params, cfg: TransformerConfig, tokens: jax.Array,
            max_seq: int) -> tuple[jax.Array, Params]:
    """Run the full prompt, return last-position logits + filled cache."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed(params["embed"], tokens).astype(cfg.dtype)
    if cfg.learned_pos:
        x = x + embed(params["pos_embed"], positions).astype(cfg.dtype)
    x = constrain(x, DP, None, None)
    acfg = cfg.attn_config()
    caches = []
    for gp, (n, use_moe) in zip(params["groups"], cfg.layer_groups()):
        def body(x, lp):
            h = rmsnorm(lp["attn_norm"], x)
            fwd = mla_forward if cfg.attn_type == "mla" else gqa_forward
            h, kv = fwd(lp["attn"], acfg, h, positions, causal=cfg.causal,
                        q_chunk=cfg.q_chunk, return_cache=True,
                        unroll=cfg.scan_unroll)
            x = x + h
            f = rmsnorm(lp["ffn_norm"], x)
            if use_moe:
                out, _ = moe_ffn(lp["moe"], cfg.moe, f)
                if cfg.dense_residual:
                    out = out + glu_ffn(lp["ffn"], f)
            else:
                out = glu_ffn(lp["ffn"], f)
            return x + out, kv

        x, kv = jax.lax.scan(body, x, gp, unroll=cfg.scan_unroll)
        # pad caches to max_seq
        kv = jax.tree.map(
            lambda a: jnp.pad(
                a, [(0, 0), (0, 0), (0, max_seq - s)] +
                [(0, 0)] * (a.ndim - 3)), kv)
        caches.append(kv)
    h = rmsnorm(params["final_norm"], x[:, -1:])
    logits = unembed(params["embed"], h)
    return logits[:, 0], caches


def decode_step(params: Params, cfg: TransformerConfig, caches: Params,
                token: jax.Array, position: jax.Array
                ) -> tuple[jax.Array, Params]:
    """One decode step.  token (B,), position (B,) → logits (B, V)."""
    b = token.shape[0]
    x = embed(params["embed"], token[:, None]).astype(cfg.dtype)
    if cfg.learned_pos:
        x = x + embed(params["pos_embed"], position[:, None]).astype(
            cfg.dtype)
    acfg = cfg.attn_config()
    new_caches = []
    for gp, cache, (n, use_moe) in zip(params["groups"], caches,
                                       cfg.layer_groups()):
        def body(x, scanned):
            lp, lc = scanned
            h = rmsnorm(lp["attn_norm"], x)
            dec = mla_decode if cfg.attn_type == "mla" else gqa_decode
            h, lc2 = dec(lp["attn"], acfg, h, lc, position)
            x = x + h
            f = rmsnorm(lp["ffn_norm"], x)
            if use_moe:
                out, _ = moe_ffn(lp["moe"], cfg.moe, f, dropless=True)
                if cfg.dense_residual:
                    out = out + glu_ffn(lp["ffn"], f)
            else:
                out = glu_ffn(lp["ffn"], f)
            return x + out, lc2

        x, cache2 = jax.lax.scan(body, x, (gp, cache),
                                 unroll=cfg.scan_unroll)
        new_caches.append(cache2)
    h = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], h)
    return logits[:, 0], new_caches


def count_params(params: Params) -> int:
    import numpy as np

    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
