from . import attention, layers, moe, nequip, recsys, transformer  # noqa: F401
