"""Shared NN layers (pure JAX, pytree params — no external NN library).

Params are nested dicts of jnp arrays.  Initialisers take an explicit
PRNG key and return the pytree; `abstract_init` wraps any init in
``jax.eval_shape`` so the dry-run can build ShapeDtypeStruct params
without allocating 671B weights.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


def abstract_init(init_fn: Callable[..., Params], *args, **kwargs) -> Params:
    """Shape-only init (no allocation) for dry-runs."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_fn(k, *args, **kwargs), key)


# -- dense ---------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": (jax.random.normal(key, (d_in, d_out)) * scale
                  ).astype(dtype)}


def dense(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def mlp_init(key, dims: list[int], dtype=jnp.float32,
             bias: bool = True) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, d_in, d_out in zip(keys, dims[:-1], dims[1:]):
        p = dense_init(k, d_in, d_out, dtype)
        if bias:
            p["b"] = jnp.zeros((d_out,), dtype)
        layers.append(p)
    return {"layers": layers}


def mlp(params: Params, x: jax.Array,
        act=jax.nn.relu, final_act=None) -> jax.Array:
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        x = x @ layer["w"].astype(x.dtype)
        if "b" in layer:
            x = x + layer["b"].astype(x.dtype)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# -- norms ---------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# -- GLU FFN ---------------------------------------------------------------
def glu_ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype)["w"],
        "w_up": dense_init(k2, d_model, d_ff, dtype)["w"],
        "w_down": dense_init(k3, d_ff, d_model, dtype,
                             scale=1.0 / math.sqrt(d_ff))["w"],
    }


def glu_ffn(params: Params, x: jax.Array) -> jax.Array:
    g = x @ params["w_gate"].astype(x.dtype)
    u = x @ params["w_up"].astype(x.dtype)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(x.dtype)


# -- rotary embeddings -------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("d", "theta"))
def rope_freqs(positions: jax.Array, d: int,
               theta: float = 10_000.0) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for RoPE.  positions (…,) → (…, d/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D) with cos/sin (..., S, D/2) — rotate pairs."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]   # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


# -- embeddings ---------------------------------------------------------------
def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02
                      ).astype(dtype)}


def embed(params: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(params["table"], ids, axis=0)


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Tied softmax head."""
    return x @ params["table"].astype(x.dtype).T


def cross_entropy_tied_chunked(h: jax.Array, table: jax.Array,
                               labels: jax.Array,
                               weights: jax.Array | None = None,
                               chunk: int = 16_384,
                               unroll: bool = False) -> jax.Array:
    """CE against a tied embedding table without materialising (…, V).

    Online logsumexp over vocabulary chunks (flash-softmax along V):
    peak memory is (…, chunk) instead of (…, V) — the §Perf fix for
    million-item softmax heads (BERT4Rec's 2²⁰-item catalogue).
    h (..., D); table (V, D); labels (...) int.
    """
    v, d = table.shape
    pad = (-v) % chunk
    n_chunks = (v + pad) // chunk
    h32 = h.astype(jnp.float32)

    def body(carry, ci):
        # remat: recompute this chunk's logits in backward — otherwise
        # the scan saves every (…, chunk) logit tile and the memory win
        # evaporates (§Perf iteration 5, refuted-then-fixed).
        @jax.checkpoint
        def inner(carry, ci):
            m, s, gold = carry
            start = ci * chunk
            tb = jax.lax.dynamic_slice_in_dim(table, start, chunk,
                                              axis=0) \
                if pad == 0 else jax.lax.dynamic_slice_in_dim(
                    jnp.pad(table, ((0, pad), (0, 0))), start, chunk,
                    axis=0)
            logits = h32 @ tb.astype(jnp.float32).T      # (..., chunk)
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1) + start
            valid = col < v
            logits = jnp.where(valid, logits, -jnp.inf)
            m2 = jnp.maximum(m, jnp.max(logits, axis=-1))
            s2 = s * jnp.exp(m - m2) + jnp.sum(
                jnp.exp(logits - m2[..., None]), axis=-1)
            hit = (col == labels[..., None])
            gold2 = gold + jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
            return (m2, s2, gold2)

        return inner(carry, ci), None

    init = (jnp.full(h.shape[:-1], -jnp.inf, jnp.float32),
            jnp.zeros(h.shape[:-1], jnp.float32),
            jnp.zeros(h.shape[:-1], jnp.float32))
    (m, s, gold), _ = jax.lax.scan(body, init,
                                   jnp.arange(n_chunks),
                                   unroll=unroll)
    nll = (m + jnp.log(jnp.maximum(s, 1e-30))) - gold
    if weights is not None:
        return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights),
                                                    1.0)
    return jnp.mean(nll)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
