"""Train state + generic sharded train-step factory.

``make_train_step`` turns any ``loss_fn(params, batch) → (loss, metrics)``
into a jit-able ``step(state, batch) → (state, metrics)`` with gradient
accumulation, optional int8 error-feedback gradient compression, and
donation-friendly layout.  Sharding is supplied at jit time by the
launcher (in_shardings/out_shardings from the rule trees).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.context import DP, constrain

from .optimizer import OptimizerConfig, make_optimizer

Params = Any


def init_train_state(params: Params, opt_cfg: OptimizerConfig) -> dict:
    opt_init, _ = make_optimizer(opt_cfg)
    return {"params": params, "opt": opt_init(params)}


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    accum_steps: int = 1,
                    compressor=None) -> Callable:
    """loss_fn(params, batch) → (loss, metrics dict)."""
    _, opt_update = make_optimizer(opt_cfg)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: dict, batch: Any) -> tuple[dict, dict]:
        params = state["params"]
        if accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            # Microbatch over the leading axis: (A, b/A, …).  Gradients
            # accumulate *in the scan carry* (param-dtype running sum) —
            # stacking per-microbatch grads would cost A× the parameter
            # memory, which no 100B+ model survives.
            # The reshape would land the batch sharding on the (small)
            # accum axis and silently replicate the microbatch — pin it
            # back onto the per-microbatch batch dim.
            micro = jax.tree.map(
                lambda x: constrain(
                    x.reshape((accum_steps, -1) + x.shape[1:]),
                    None, DP, *([None] * (x.ndim - 1))),
                batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                              params)

            def acc(carry, mb):
                gsum, lsum = carry
                loss, metrics, grads = grads_of(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), gsum, grads)
                return (gsum, lsum + loss), metrics

            (gsum, lsum), metricss = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), micro)
            loss = lsum / accum_steps
            metrics = jax.tree.map(jnp.mean, metricss)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)

        if compressor is not None:
            grads, state = compressor(grads, state)

        new_params, new_opt, opt_metrics = opt_update(
            grads, state["opt"], params)
        new_state = {**state, "params": new_params, "opt": new_opt}
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return step
