from . import checkpoint, fault, optimizer, train_state  # noqa: F401
