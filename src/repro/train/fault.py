"""Fault tolerance: supervisor loop, straggler mitigation, elasticity.

At thousand-node scale the question is not *if* a host dies mid-run but
*how often*.  The supervisor wraps the train loop with:

 * **checkpoint/restart** — on any step failure, restore the latest
   committed checkpoint and replay (the data pipeline is step-seeded, so
   replay is deterministic);
 * **retry budget** — transient failures (preempted host, flaky ICI
   link) retry in place; persistent ones re-raise after ``max_restarts``;
 * **straggler mitigation** — per-step deadline tracking; hosts that
   exceed ``straggler_factor ×`` the moving-median step time get their
   data shard skipped-and-repaired (recorded, re-enqueued), so one slow
   host does not stall the synchronous collective;
 * **elastic restart** — on restore, the mesh may have a different
   size/shape; ``restore_checkpoint`` reshards into the new topology and
   the data sharder re-balances (tested in ``tests/test_fault.py``).

On CPU CI, failures are injected via the ``fault_injector`` hook; on a
real pod the same supervisor catches ``XlaRuntimeError`` from dead
hosts.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import numpy as np

from .checkpoint import (cleanup_old, latest_step, restore_checkpoint,
                         save_checkpoint)

log = logging.getLogger("repro.fault")


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 5
    straggler_factor: float = 3.0
    straggler_window: int = 20
    async_ckpt: bool = True


@dataclass
class StragglerMonitor:
    """Deadline-based straggler detection over a moving median."""

    factor: float = 3.0
    window: int = 20
    times: list[float] = field(default_factory=list)
    skipped_steps: list[int] = field(default_factory=list)

    def deadline(self) -> float | None:
        if len(self.times) < 5:
            return None
        return float(np.median(self.times[-self.window:])) * self.factor

    def record(self, dt: float) -> None:
        self.times.append(dt)

    def is_straggler(self, dt: float) -> bool:
        d = self.deadline()
        return d is not None and dt > d

    def skip_and_repair(self, step: int) -> None:
        """Mark the step's slow shard skipped; repair = re-enqueue."""
        self.skipped_steps.append(step)


class Supervisor:
    """Run a train loop under fault tolerance.

    ``step_fn(state, batch) → (state, metrics)`` (jitted),
    ``data_fn(step) → batch`` must be step-addressable (deterministic
    replay after restore).
    """

    def __init__(self, cfg: FaultConfig, step_fn: Callable,
                 data_fn: Callable[[int], Any],
                 fault_injector: Callable[[int], None] | None = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.fault_injector = fault_injector
        self.monitor = StragglerMonitor(cfg.straggler_factor,
                                        cfg.straggler_window)
        self.restarts = 0
        self.pending_ckpt = None

    def _save(self, step: int, state: Any) -> None:
        if self.pending_ckpt is not None:
            self.pending_ckpt.join()
        self.pending_ckpt = save_checkpoint(
            self.cfg.ckpt_dir, step, state,
            blocking=not self.cfg.async_ckpt)
        cleanup_old(self.cfg.ckpt_dir, self.cfg.keep)

    def _restore(self, state_template: Any, shardings: Any | None):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return 0, None
        state = restore_checkpoint(self.cfg.ckpt_dir, step,
                                   state_template, shardings)
        return step + 1, state

    def run(self, state: Any, n_steps: int,
            shardings: Any | None = None,
            on_metrics: Callable[[int, dict], None] | None = None) -> Any:
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype), state)
        step = 0
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if self.fault_injector is not None:
                    self.fault_injector(step)
                batch = self.data_fn(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(
                    jax.tree.leaves(metrics)[0]
                    if jax.tree.leaves(metrics) else
                    jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0
                if self.monitor.is_straggler(dt):
                    log.warning("step %d straggled (%.3fs) — shard "
                                "skip-and-repair", step, dt)
                    self.monitor.skip_and_repair(step)
                self.monitor.record(dt)
                if on_metrics:
                    on_metrics(step, metrics)
                if (step + 1) % self.cfg.ckpt_every == 0:
                    self._save(step, state)
                step += 1
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d", step,
                          type(e).__name__, self.restarts,
                          self.cfg.max_restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                restored_step, restored = self._restore(template,
                                                        shardings)
                if restored is not None:
                    state = restored
                    step = restored_step
                # else: replay from the current in-memory state
        if self.pending_ckpt is not None:
            self.pending_ckpt.join()
        return state
