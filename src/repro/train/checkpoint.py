"""Sharded, async, atomic checkpointing with cross-mesh restore.

Layout (one directory per step):
  ckpt_dir/
    step_000123/
      manifest.json          # tree structure, shapes, dtypes, mesh
      shard_h<host>.npz      # this host's addressable shard payloads
    LATEST                   # atomically updated pointer file

Properties needed at 1000-node scale:
 * each host writes only its addressable shards (no gather to host 0);
 * a checkpoint is visible only after its manifest + LATEST pointer are
   atomically renamed into place — a crash mid-write never corrupts the
   restore path;
 * async: the state is snapshotted to host RAM on the train thread,
   serialisation happens on a background thread;
 * elastic restore: a checkpoint saved on one mesh can be restored on a
   *different* mesh/topology — shards are reassembled from the manifest
   and resharded to the new sharding (the paper's exact-byte ethos: each
   host reads only the byte ranges its new shards need).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def tree_paths(tree: Any) -> list[str]:
    return list(_flatten(tree))


def save_checkpoint(ckpt_dir: str | os.PathLike, step: int, state: Any,
                    blocking: bool = True) -> threading.Thread | None:
    """Write ``state`` (pytree of jax/np arrays) for ``step``."""
    ckpt_dir = Path(ckpt_dir)
    host = jax.process_index()
    flat = _flatten(state)

    # snapshot to host memory (cheap on CPU; device→host on TPU)
    arrays: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for key, leaf in flat.items():
        if isinstance(leaf, jax.Array):
            shards = [s for s in leaf.addressable_shards]
            shape = leaf.shape
            for s in shards:
                arrays[f"{key}#{_idx_key(s.index, shape)}"] = \
                    np.asarray(s.data)
            meta[key] = {
                "shape": list(shape),
                "dtype": str(leaf.dtype),
                "shards": [
                    {"index": _idx_json(s.index, shape),
                     "file_key": f"{key}#{_idx_key(s.index, shape)}",
                     "host": host} for s in shards],
            }
        else:
            arrays[f"{key}#full"] = np.asarray(leaf)
            meta[key] = {"shape": list(np.shape(leaf)),
                         "dtype": str(np.asarray(leaf).dtype),
                         "shards": [{"index": None,
                                     "file_key": f"{key}#full",
                                     "host": host}]}

    def write():
        step_dir = ckpt_dir / f"step_{step:09d}"
        tmp = ckpt_dir / f".tmp_step_{step:09d}_h{host}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"shard_h{host}.npz", **arrays)
        if host == 0:
            (tmp / "manifest.json").write_text(json.dumps(
                {"step": step, "tree": meta,
                 "n_hosts": jax.process_count(),
                 "time": time.time()}, indent=1))
        step_dir.mkdir(parents=True, exist_ok=True)
        for f in tmp.iterdir():
            os.replace(f, step_dir / f.name)
        tmp.rmdir()
        if host == 0:
            latest_tmp = ckpt_dir / ".LATEST.tmp"
            latest_tmp.write_text(str(step))
            os.replace(latest_tmp, ckpt_dir / "LATEST")   # atomic commit

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def _norm(index, shape):
    out = []
    for s, dim in zip(index, shape):
        start = 0 if s.start is None else int(s.start)
        stop = dim if s.stop is None else int(s.stop)
        out.append((start, stop))
    return out


def _idx_key(index, shape) -> str:
    return "_".join(f"{a}-{b}" for a, b in _norm(index, shape)) or "scalar"


def _idx_json(index, shape):
    return [list(x) for x in _norm(index, shape)]


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore_checkpoint(ckpt_dir: str | os.PathLike, step: int,
                       target: Any, shardings: Any | None = None) -> Any:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching tree of Sharding — may
    describe a *different* mesh than the one that saved (elastic).
    """
    step_dir = Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    payloads = {}
    for f in sorted(step_dir.glob("shard_h*.npz")):
        with np.load(f) as z:
            payloads.update({k: z[k] for k in z.files})

    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}

    out_flat = {}
    for key, leaf in flat_target.items():
        info = manifest["tree"][key]
        full = np.zeros(tuple(info["shape"]),
                        dtype=np.dtype(info["dtype"]))
        for sh in info["shards"]:
            data = payloads[sh["file_key"]]
            if sh["index"] is None:
                full = data
            else:
                sl = tuple(slice(a, b) for a, b in sh["index"])
                full[sl] = data
        if key in flat_shard and flat_shard[key] is not None:
            out_flat[key] = jax.device_put(full, flat_shard[key])
        else:
            out_flat[key] = jax.device_put(full) if isinstance(
                leaf, jax.Array) else full

    return _unflatten_like(target, out_flat)


def _unflatten_like(target: Any, flat: dict[str, Any]) -> Any:
    leaves_with_path = jax.tree_util.tree_flatten_with_path(target)
    paths = [SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
             for path, _ in leaves_with_path[0]]
    treedef = leaves_with_path[1]
    return jax.tree_util.tree_unflatten(
        treedef, [flat[p] for p in paths])


def cleanup_old(ckpt_dir: str | os.PathLike, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(int(d.name.split("_")[1])
                   for d in ckpt_dir.glob("step_*"))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}", ignore_errors=True)
