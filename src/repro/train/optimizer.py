"""Optimizers from scratch: AdamW and Adafactor (+ schedules, clipping).

Adafactor (factored second moments) is what makes 671B-parameter MoE
training states fit: state per (…, R, C) matrix is R + C floats instead
of R·C.  Both optimizers are pure pytree transforms; ZeRO-style state
sharding comes from ``repro.distributed.sharding.opt_state_specs``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"               # "adamw" | "adafactor"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # adafactor
    decay_rate: float = 0.8
    epsilon1: float = 1e-30
    epsilon2: float = 1e-3


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# -- AdamW -----------------------------------------------------------------
def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimizerConfig, grads: Params, state: dict,
                 params: Params) -> tuple[Params, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# -- Adafactor ----------------------------------------------------------------
def adafactor_init(params: Params) -> dict:
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(factored, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads: Params, state: dict,
                     params: Params) -> tuple[Params, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)

    def upd(p, g, f):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.epsilon1
        if p.ndim >= 2:
            vr = beta2 * f["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * f["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True), cfg.epsilon1)
            upd_ = g32 / (jnp.sqrt(rfac)[..., None] *
                          jnp.sqrt(vc)[..., None, :] + cfg.epsilon2)
            newf = {"vr": vr, "vc": vc}
        else:
            v = beta2 * f["v"] + (1 - beta2) * g2
            upd_ = g32 / (jnp.sqrt(v) + cfg.epsilon2)
            newf = {"v": v}
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-30)
        upd_ = upd_ / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd_).astype(p.dtype), newf

    out = jax.tree_util.tree_map(
        upd, params, grads, state["f"],
        is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
    # out is a tree of (param, state) tuples at the param leaves
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_f = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"f": new_f, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.kind == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(cfg, g, s, p)
    if cfg.kind == "adafactor":
        return adafactor_init, lambda g, s, p: adafactor_update(cfg, g, s, p)
    raise ValueError(cfg.kind)
