"""On-device batched extraction for 2-D polytopes on regular grids.

The host slicer (Algorithm 1) plans one request at a time in float64.
Training pipelines want the opposite trade: *many congruent small
requests per step* (batched country crops, per-sample regions of
interest) with static shapes, planned on the accelerator itself.

This module runs one BFS layer of Algorithm 1 as a batched device
computation: for a batch of convex 2-D polytopes over regular ordered
axes,

  1. per-polytope extents on axis 0 → index ranges (``searchsorted``),
  2. slice every (polytope × row) pair at once — the
     ``repro.kernels.slice`` Pallas kernel (or its jnp oracle),
  3. per-row 1-D extents on axis 1 → index ranges,
  4. emit a padded (P, R, C) offset lattice + validity mask — the
     batched extraction plan consumed by ``gather_rows``.

Shapes are static: R = max rows, C = max columns per row; masked slots
are -1 (exactly the padding convention of the gather/bag kernels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._casting import checked_cast_i32, ensure_i32_addressable
from repro.kernels.slice import ref as slice_ref


@functools.partial(jax.jit, static_argnames=("max_rows", "max_cols",
                                             "n0", "n1"))
def batched_plan_2d(verts: jax.Array, valid: jax.Array,
                    axis0: jax.Array, axis1: jax.Array,
                    n0: int, n1: int,
                    max_rows: int, max_cols: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Plan a batch of convex 2-D polytopes on a regular (n0 × n1) grid.

    verts  — (P, V, 2) float32 polytope vertices (axis0, axis1 coords)
    valid  — (P, V) bool vertex mask
    axis0  — (n0,) sorted axis-0 index values
    axis1  — (n1,) sorted axis-1 index values

    Returns (offsets (P, max_rows, max_cols) int32 flat offsets with -1
    padding, n_points (P,)).
    """
    p, v, _ = verts.shape
    big = jnp.asarray(jnp.inf, verts.dtype)

    c0 = jnp.where(valid, verts[:, :, 0], big)
    lo0 = jnp.min(c0, axis=1)
    hi0 = jnp.max(jnp.where(valid, verts[:, :, 0], -big), axis=1)

    # rows intersecting each polytope
    start = jnp.searchsorted(axis0, lo0 - 1e-6, side="left")  # (P,)
    row_ids = start[:, None] + jnp.arange(max_rows)[None, :]  # (P, R)
    row_ok = (row_ids < n0) & \
        (axis0[jnp.clip(row_ids, 0, n0 - 1)] <= hi0[:, None] + 1e-6)
    row_vals = axis0[jnp.clip(row_ids, 0, n0 - 1)]

    # slice every (polytope, row) pair via the shared slicing core —
    # extents of the remaining coordinate only, so the (V × V) candidate
    # lattice never materializes (same math as the old slice_batch +
    # masked min/max, fused).
    scale = jnp.maximum(1.0, jnp.max(jnp.abs(verts[:, :, 0]), axis=1))
    lo1, hi1, hit2 = slice_ref.slice_minor_extents(
        verts[:, None, :, 0], verts[:, None, :, 1], valid[:, None, :],
        row_vals, (slice_ref.PLANE_TOL * scale)[:, None])
    lo1 = lo1.reshape(p * max_rows)
    hi1 = hi1.reshape(p * max_rows)
    hit = hit2.reshape(p * max_rows) & row_ok.reshape(-1)

    c_start = jnp.searchsorted(axis1, lo1 - 1e-6, side="left")
    col_ids = c_start[:, None] + jnp.arange(max_cols)[None, :]
    col_ok = (col_ids < n1) & \
        (axis1[jnp.clip(col_ids, 0, n1 - 1)] <= hi1[:, None] + 1e-6) & \
        hit[:, None]

    # n0/n1 are static, so this guard runs at trace time: a grid whose
    # flat offsets overflow int32 fails loudly instead of truncating.
    ensure_i32_addressable(n0 * n1, what="batched_plan_2d grid")
    offsets = checked_cast_i32(jnp.where(
        col_ok,
        row_ids.reshape(-1)[:, None] * n1 + jnp.clip(col_ids, 0, n1 - 1),
        -1), what="batched_plan_2d offsets", allow_negative_one=True)
    offsets = offsets.reshape(p, max_rows, max_cols)
    n_points = jnp.sum(offsets >= 0, axis=(1, 2))
    return offsets, n_points


def batched_plan_runs_2d(verts: jax.Array, valid: jax.Array,
                         axis0: jax.Array, axis1: jax.Array,
                         max_rows: int, use_pallas: bool = False,
                         interpret: bool = True):
    """Run-pair form of :func:`batched_plan_2d`: the compressed plan
    representation, straight from the fused pipeline.

    Same geometry/tolerance conventions as the offset-lattice path (the
    f32 ``1e-6`` regime), but emits compacted ``(run_start, run_length)``
    pairs instead of the padded (P, R, C) lattice — rows become single
    entries regardless of width, and the output feeds
    ``kernels.gather.gather_plan_runs`` burst DMA directly.  Returns
    (run_starts (M,) i32, run_lengths (M,) i32, meta (3,) i32 =
    [n_runs, n_rows, n_points]) flat across the batch in
    (polytope, row) order.
    """
    from repro.kernels.plan import ops as plan_ops

    p = verts.shape[0]
    n0, n1 = int(axis0.shape[0]), int(axis1.shape[0])
    ensure_i32_addressable(n0 * n1, what="batched_plan_runs_2d grid")
    # scalars layout: [eps0, eps1, plane_tol_rel, period]
    scalars = jnp.asarray([1e-6, 1e-6, slice_ref.PLANE_TOL, 0.0],
                          verts.dtype)
    rowoff = jnp.arange(0, n0 * n1, n1, dtype=jnp.int32)
    return plan_ops.plan_runs_2d(
        verts, valid, jnp.zeros(p, jnp.int32), axis0, rowoff, axis1,
        scalars, n0=n0, n1=n1, max_rows=max_rows, cyclic=False,
        use_pallas=use_pallas, interpret=interpret)


def batched_extract_2d(flat_data: jax.Array, verts, valid, axis0, axis1,
                       max_rows: int, max_cols: int):
    """Plan + gather in one jit: (P, max_rows·max_cols) values with 0 at
    padded slots, plus the offset lattice."""
    n0, n1 = int(axis0.shape[0]), int(axis1.shape[0])
    offsets, n_points = batched_plan_2d(verts, valid, axis0, axis1,
                                        n0, n1, max_rows, max_cols)
    flat_off = offsets.reshape(offsets.shape[0], -1)
    vals = jnp.where(flat_off >= 0,
                     jnp.take(flat_data, jnp.maximum(flat_off, 0)),
                     0)
    return vals, offsets, n_points
