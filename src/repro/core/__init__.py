# The paper's primary contribution: the Polytope feature-extraction
# engine — geometry, axes, datacubes, Algorithm-1 slicer, index trees,
# extraction plans and executors (plus the bounding-box / whole-field
# baselines the paper compares against).
from .axes import (Axis, CategoricalAxis, CyclicAxis, CyclicTransform,
                   MappedTransform, MergedTransform, OrderedAxis, Transform)
from .batched import batched_extract_2d, batched_plan_2d, batched_plan_runs_2d
from .datacube import (BranchingDatacube, Datacube, OctahedralGridDatacube,
                       TensorDatacube, TransformedDatacube)
from .delta_planner import DeltaPlanner
from .device_planner import DevicePlanner
from .extractor import (BoundingBoxExtractor, ExtractResult,
                        PolytopeExtractor, TraditionalExtractor, gather)
from .geometry import Polytope, box_polytope, regular_polygon, slice_vertices
from .hull import convex_hull_prune
from .index_tree import (CompressedPlan, ExtractionPlan, IndexNode,
                         assemble_plan, coalesce_runs, compress_plan,
                         decompress_plan, flatten)
from .shapes import (CANON_TOL, All, Box, ConvexPolytope, Disk, Ellipsoid,
                     Path, Point, Polygon, Request, Select, Shape, Span,
                     Union, canonical_hash, canonical_key, ear_clip,
                     shape_signature, signature_hash)
from .slicer import Slicer, SliceStats

__all__ = [
    "Axis", "CategoricalAxis", "CyclicAxis", "OrderedAxis",
    "Transform", "CyclicTransform", "MappedTransform", "MergedTransform",
    "BranchingDatacube", "Datacube", "OctahedralGridDatacube",
    "TensorDatacube", "TransformedDatacube",
    "BoundingBoxExtractor", "ExtractResult",
    "PolytopeExtractor", "TraditionalExtractor", "gather", "Polytope",
    "box_polytope", "regular_polygon", "slice_vertices",
    "convex_hull_prune", "ExtractionPlan", "IndexNode", "coalesce_runs",
    "flatten", "assemble_plan", "CompressedPlan", "compress_plan",
    "decompress_plan",
    "DeltaPlanner", "DevicePlanner", "All", "Box", "ConvexPolytope",
    "Disk", "Ellipsoid", "Path",
    "Point", "Polygon", "Request", "Select", "Shape", "Span", "Union",
    "ear_clip", "Slicer", "SliceStats", "batched_extract_2d",
    "batched_plan_2d", "batched_plan_runs_2d", "CANON_TOL",
    "canonical_hash", "canonical_key", "shape_signature",
    "signature_hash",
]
