"""Index tree (paper §3.2 "Index Tree Construction") and extraction plans.

The slicer builds the tree breadth-first; interior levels are Python
nodes (few — one per selected index on the *upper* axes), while the
deepest ordered axis stores its selected indices as **vector leaf
blocks** (positions + values arrays).  This is the host-side analogue of
the paper's observation that 1-D slices dominate: we never materialise
them as objects, we emit them as arrays.

Flattening a tree yields an :class:`ExtractionPlan`: flat element
offsets into the datacube storage (the "precise bytes"), coalesced into
contiguous ``(start, length)`` runs for burst-friendly I/O, plus the
coordinates of every extracted point.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from .datacube import Datacube


@dataclass
class IndexNode:
    """One selected index on one axis."""

    axis: str | None = None          # None for the root
    pos: int = -1                    # storage position on the axis
    value: Any = None                # axis value (unwrapped for cyclic)
    children: dict[int, "IndexNode"] = field(default_factory=dict)
    # Vector leaf block: selected positions/values on the *next* axis.
    leaf_axis: str | None = None
    leaf_positions: np.ndarray | None = None
    leaf_values: np.ndarray | None = None
    # True iff this node's path addresses a single datacube element.  A
    # node with no children, no leaf block and complete=False is a
    # *dangling* branch (all its candidate children were sliced away) and
    # contributes nothing.
    complete: bool = False

    def child(self, axis: str, pos: int, value: Any) -> "IndexNode":
        node = self.children.get(pos)
        if node is None:
            node = IndexNode(axis=axis, pos=pos, value=value)
            self.children[pos] = node
        return node

    def add_leaf_block(self, axis: str, positions: np.ndarray,
                       values: np.ndarray) -> None:
        if self.leaf_positions is None:
            self.leaf_axis = axis
            self.leaf_positions = np.asarray(positions, np.int64)
            self.leaf_values = np.asarray(values, np.float64)
        else:
            # Union merge (paper Fig 8c): concatenate then dedupe by pos.
            pos = np.concatenate([self.leaf_positions, positions])
            val = np.concatenate([self.leaf_values, values])
            _, first = np.unique(pos, return_index=True)
            first.sort()
            self.leaf_positions = pos[first].astype(np.int64)
            self.leaf_values = val[first]

    # -- stats ------------------------------------------------------------
    def n_points(self) -> int:
        n = 0 if self.leaf_positions is None else len(self.leaf_positions)
        if self.complete:
            n += 1
        return n + sum(c.n_points() for c in self.children.values())

    def depth(self) -> int:
        d = 1 if self.leaf_positions is not None else 0
        if self.children:
            d = max(d, 1 + max(c.depth() for c in self.children.values()))
        return d


@dataclass
class ExtractionPlan:
    """The paper's output: the precise elements to read."""

    offsets: np.ndarray                    # (N,) int64 flat element offsets
    run_starts: np.ndarray                 # (R,) int64
    run_lengths: np.ndarray                # (R,) int64
    coords: dict[str, np.ndarray]          # axis -> (N,) values
    itemsize: int = 8

    @property
    def n_points(self) -> int:
        return int(len(self.offsets))

    @property
    def nbytes(self) -> int:
        """Bytes this plan reads — the paper's headline metric."""
        return self.n_points * self.itemsize

    @property
    def n_runs(self) -> int:
        return int(len(self.run_starts))


def flatten(root: IndexNode, datacube: Datacube) -> ExtractionPlan:
    """Walk the tree and emit the extraction plan (vectorised leaves)."""
    offsets: list[np.ndarray] = []
    coord_cols: dict[str, list[np.ndarray]] = {}

    def walk(node: IndexNode, path: dict[str, int],
             coord: dict[str, Any]) -> None:
        if node.leaf_positions is not None:
            n = len(node.leaf_positions)
            offs = datacube.leaf_offsets(path, node.leaf_positions)
            offsets.append(offs.astype(np.int64))
            for ax_name, v in coord.items():
                coord_cols.setdefault(ax_name, []).append(np.full(n, v))
            coord_cols.setdefault(node.leaf_axis, []).append(
                np.asarray(node.leaf_values))
        if node.complete:  # fully-assigned scalar leaf
            offsets.append(np.array([datacube.base_offset(path)], np.int64))
            for ax_name, v in coord.items():
                coord_cols.setdefault(ax_name, []).append(np.array([v]))
        if not node.children:
            return
        for child in node.children.values():
            path[child.axis] = child.pos
            coord[child.axis] = child.value
            walk(child, path, coord)
            del path[child.axis]
            del coord[child.axis]

    walk(root, {}, {})

    if offsets:
        offs = np.concatenate(offsets)
    else:
        offs = np.empty(0, np.int64)
    coords = {}
    n_total = len(offs)
    for ax_name, cols in coord_cols.items():
        col = np.concatenate(cols)
        if len(col) == n_total:
            coords[ax_name] = col
    return assemble_plan(offs, coords, datacube.dtype.itemsize)


def assemble_plan(offs: np.ndarray, coords: dict[str, np.ndarray],
                  itemsize: int) -> ExtractionPlan:
    """Sort, coalesce and wrap raw (offsets, coords) into a plan.

    Plans are emitted in ascending storage order: runs become ascending
    burst reads and sortedness is a checkable invariant
    (repro.analysis.plan_check).  Tree-walk order is *almost* storage
    order already, but e.g. a seam-straddling cyclic range emits the
    wrapped sub-interval after the unwrapped one; the coordinate
    columns are permuted in lockstep so point↔coord pairing is intact.
    Shared by :func:`flatten` and the delta planner's splice path
    (core/delta_planner.py), so a spliced plan goes through the exact
    emission discipline of a cold one.
    """
    n_total = len(offs)
    order = np.argsort(offs, kind="stable")
    if not np.array_equal(order, np.arange(n_total)):
        offs = offs[order]
        coords = {k: v[order] for k, v in coords.items()}
    starts, lengths = coalesce_runs(offs)
    return ExtractionPlan(offsets=offs, run_starts=starts,
                          run_lengths=lengths, coords=coords,
                          itemsize=itemsize)


def coalesce_runs(offsets: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Merge consecutive offsets into (start, length) runs.

    The deepest ordered axis is storage-minor in all our cubes, so the
    plan's offsets arrive largely presorted in contiguous stretches —
    these become long burst reads (paper §5.4: hardware with fast
    random read benefits; HBM wants bursts).
    """
    if len(offsets) == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    brk = np.flatnonzero(np.diff(offsets) != 1)
    starts_idx = np.concatenate([[0], brk + 1])
    ends_idx = np.concatenate([brk, [len(offsets) - 1]])
    return offsets[starts_idx].copy(), (ends_idx - starts_idx + 1).astype(
        np.int64)


@dataclass
class CompressedPlan:
    """Delta-encoded int32 plan: the wire/cache form of the run list.

    A sorted, deduplicated plan's runs are strictly ascending and
    non-overlapping, so ``start[i] − (start[i−1] + length[i−1]) ≥ 1``
    for every i > 0 — the *gaps* between runs are small positive
    integers even when absolute offsets approach 2⁶³.  Store one int64
    anchor plus int32 gap/length columns: 8 + 8·R bytes instead of the
    plan's 8·N offsets, a ~N/R · 2 compression on burst-friendly plans.

    ``compress_plan`` validates every column through
    ``checked_cast_i32`` — a gap or length past 2³¹ raises
    ``OverflowError`` instead of truncating, and the caller keeps the
    uncompressed plan (host fallback).
    """

    base: int                              # int64 anchor: first run start
    start_gaps: np.ndarray                 # (R,) int32; gaps[0] == 0
    run_lengths: np.ndarray                # (R,) int32
    itemsize: int = 8

    @property
    def n_runs(self) -> int:
        return int(len(self.run_lengths))

    @property
    def n_points(self) -> int:
        return int(self.run_lengths.sum()) if self.n_runs else 0

    @property
    def nbytes_encoded(self) -> int:
        """Size of the encoded form itself (anchor + two i32 columns)."""
        return 8 + 8 * self.n_runs


def compress_plan(plan: ExtractionPlan) -> CompressedPlan:
    """Delta-encode a plan's runs into :class:`CompressedPlan`."""
    from repro.kernels._casting import checked_cast_i32

    starts = np.asarray(plan.run_starts, np.int64)
    lengths = np.asarray(plan.run_lengths, np.int64)
    if len(starts) == 0:
        empty = np.empty(0, np.int32)
        return CompressedPlan(base=0, start_gaps=empty, run_lengths=empty,
                              itemsize=plan.itemsize)
    gaps = np.concatenate([[0], starts[1:] - (starts[:-1] + lengths[:-1])])
    if np.any(gaps[1:] <= 0):
        raise ValueError("plan runs are not sorted/disjoint — cannot "
                         "delta-encode (run flatten/coalesce first)")
    return CompressedPlan(
        base=int(starts[0]),
        start_gaps=np.asarray(checked_cast_i32(
            gaps, what="compressed plan start gaps")),
        run_lengths=np.asarray(checked_cast_i32(
            lengths, what="compressed plan run lengths")),
        itemsize=plan.itemsize)


def decompress_plan(cp: CompressedPlan) -> ExtractionPlan:
    """Exact inverse of :func:`compress_plan` (offsets re-expanded)."""
    lengths = cp.run_lengths.astype(np.int64)
    if len(lengths) == 0:
        e = np.empty(0, np.int64)
        return ExtractionPlan(offsets=e, run_starts=e.copy(),
                              run_lengths=e.copy(), coords={},
                              itemsize=cp.itemsize)
    starts = (cp.base + np.cumsum(cp.start_gaps.astype(np.int64))
              + np.concatenate([[0], np.cumsum(lengths[:-1])]))
    ends = np.cumsum(lengths)
    total = int(ends[-1])
    offsets = (np.repeat(starts, lengths)
               + np.arange(total, dtype=np.int64)
               - np.repeat(ends - lengths, lengths))
    return ExtractionPlan(offsets=offsets, run_starts=starts,
                          run_lengths=lengths, coords={},
                          itemsize=cp.itemsize)
