"""Datacube abstractions (paper §3.1).

A datacube is a *possibly non-regular, imbalanced tree* of axes (paper
Fig. 2): the axis sequence below a node may depend on the index chosen
at that node.  Three concrete cubes:

* ``TensorDatacube``       — regular dense hyper-rectangle (the common case).
* ``OctahedralGridDatacube`` — ECMWF O-grid: the number of longitude
  points depends on the latitude row.  This is the real non-regular,
  imbalanced structure behind the paper's Table 1 (an O1280 field is
  6 599 680 points = "50.4 MB" at float64).
* ``BranchingDatacube``    — a leading categorical axis whose value selects
  a child cube with entirely different axes (paper Fig. 2 `val4 → x,y,z`
  vs `val5 → u,v`).
* ``TransformedDatacube``  — a regular cube viewed through axis
  transforms (cyclic/merged/mapped, DESIGN.md §2.5): the slicer plans in
  logical coordinates, offsets resolve to storage coordinates.

All cubes expose *flat element offsets*: the extraction plan ends in
byte-precise positions into the flat storage, which is exactly what the
paper's I/O layer consumes.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Mapping, Sequence

import numpy as np

from .axes import (Axis, CategoricalAxis, CyclicAxis, OrderedAxis,
                   Transform)


class Datacube:
    """Interface used by the slicer."""

    dtype: np.dtype = np.dtype(np.float64)

    # -- cyclic metadata ---------------------------------------------------
    def axis_periods(self) -> dict[str, float]:
        """Period per cyclic *logical* axis (empty when none).

        Consumed by request canonicalization (``Request.canonical_hash``)
        so that seam-straddling requests shifted by whole periods share a
        plan-cache key (DESIGN.md §2.5).
        """
        return {}

    # -- tree navigation -------------------------------------------------
    def next_axis(self, path: Mapping[str, int]) -> str | None:
        """Name of the first unassigned axis under ``path`` (natural
        order), or None when ``path`` addresses a single element."""
        raise NotImplementedError

    def axis(self, name: str, path: Mapping[str, int]) -> Axis:
        """Axis object for ``name`` given the partial assignment.  For
        non-regular cubes the returned axis depends on ``path``."""
        raise NotImplementedError

    # -- offsets -----------------------------------------------------------
    def base_offset(self, path: Mapping[str, int]) -> int:
        """Flat element offset of the subtree addressed by ``path`` (all
        assigned axes must form a prefix of the natural order)."""
        raise NotImplementedError

    def leaf_offsets(self, path: Mapping[str, int],
                     positions: np.ndarray) -> np.ndarray:
        """Flat offsets for a vector of positions on the *last* axis."""
        return self.base_offset(path) + np.asarray(positions, np.int64)

    # -- sizes -------------------------------------------------------------
    @property
    def n_elements(self) -> int:
        raise NotImplementedError

    @property
    def nbytes(self) -> int:
        return self.n_elements * self.dtype.itemsize


class TensorDatacube(Datacube):
    """Regular dense datacube over a fixed list of axes."""

    def __init__(self, axes: Sequence[Axis], dtype=np.float64):
        self._axes = list(axes)
        self._names = tuple(a.name for a in self._axes)
        self.dtype = np.dtype(dtype)
        sizes = [len(a) for a in self._axes]
        strides = np.ones(len(sizes), np.int64)
        for i in range(len(sizes) - 2, -1, -1):
            strides[i] = strides[i + 1] * sizes[i + 1]
        self._sizes = sizes
        self._strides = {n: int(s) for n, s in zip(self._names, strides)}

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self._names

    def next_axis(self, path: Mapping[str, int]) -> str | None:
        for n in self._names:
            if n not in path:
                return n
        return None

    def axis(self, name: str, path: Mapping[str, int]) -> Axis:
        return self._axes[self._names.index(name)]

    def stride(self, name: str) -> int:
        return self._strides[name]

    def logical_stride(self, name: str) -> int:
        """Flat-offset increment per +1 step of ``name``'s position —
        identical to :meth:`stride` on a regular cube (the
        transform-aware spelling lives on ``TransformedDatacube``)."""
        return self._strides[name]

    def base_offset(self, path: Mapping[str, int]) -> int:
        return int(sum(self._strides[n] * p for n, p in path.items()))

    @property
    def n_elements(self) -> int:
        return int(np.prod(self._sizes)) if self._sizes else 0

    def shape(self) -> tuple[int, ...]:
        return tuple(self._sizes)

    def axis_periods(self) -> dict[str, float]:
        return {a.name: a.period for a in self._axes
                if isinstance(a, CyclicAxis)}


class OctahedralGridDatacube(Datacube):
    """ECMWF octahedral reduced-Gaussian grid O<N> with leading axes.

    Storage layout matches GRIB: fields are concatenated latitude rows,
    row ``r`` (pole-to-pole, ``2N`` rows) holding ``n_lon(r)`` points.
    Leading axes (e.g. time, level) are regular.  The longitude axis is
    *row-dependent* — the paper's non-regular imbalanced branching.
    """

    def __init__(self, leading_axes: Sequence[Axis], n: int = 1280,
                 dtype=np.float64):
        self.n = int(n)
        self._leading = list(leading_axes)
        self._lead_names = tuple(a.name for a in self._leading)
        self.dtype = np.dtype(dtype)

        # rows 0..2N-1 from north pole to south pole
        counts_north = 20 + 4 * np.arange(self.n)          # row i: 20+4i
        self.row_counts = np.concatenate([counts_north, counts_north[::-1]])
        self.row_offsets = np.concatenate(
            [[0], np.cumsum(self.row_counts)]).astype(np.int64)
        self.points_per_field = int(self.row_offsets[-1])

        # Approximate Gaussian latitudes (exactness irrelevant to byte
        # accounting; spacing matches O-grid density).
        j = np.arange(2 * self.n)
        theta = np.pi * (j + 0.5) / (2 * self.n)
        self.latitudes = 90.0 - np.degrees(theta)
        # Storage order is row order (descending latitude); OrderedAxis
        # keeps the storage-position map internally.
        self._lat_axis = OrderedAxis("lat", self.latitudes)

        lead_sizes = [len(a) for a in self._leading]
        strides = np.ones(len(lead_sizes), np.int64) * self.points_per_field
        for i in range(len(lead_sizes) - 2, -1, -1):
            strides[i] = strides[i + 1] * lead_sizes[i + 1]
        self._lead_strides = {n_: int(s) for n_, s in
                              zip(self._lead_names, strides)}
        self._lead_sizes = lead_sizes

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self._lead_names + ("lat", "lon")

    def next_axis(self, path: Mapping[str, int]) -> str | None:
        for n_ in self._lead_names:
            if n_ not in path:
                return n_
        if "lat" not in path:
            return "lat"
        if "lon" not in path:
            return "lon"
        return None

    @lru_cache(maxsize=4096)
    def _lon_axis(self, row: int) -> CyclicAxis:
        cnt = int(self.row_counts[row])
        vals = 360.0 * np.arange(cnt) / cnt
        return CyclicAxis("lon", vals, period=360.0)

    def axis(self, name: str, path: Mapping[str, int]) -> Axis:
        if name in self._lead_names:
            return self._leading[self._lead_names.index(name)]
        if name == "lat":
            return self._lat_axis
        if name == "lon":
            if "lat" not in path:
                raise ValueError("lon axis requires lat assignment")
            return self._lon_axis(int(path["lat"]))
        raise KeyError(name)

    def base_offset(self, path: Mapping[str, int]) -> int:
        off = 0
        for n_, p in path.items():
            if n_ in self._lead_strides:
                off += self._lead_strides[n_] * p
            elif n_ == "lat":
                off += int(self.row_offsets[p])
            elif n_ == "lon":
                off += int(p)
        return off

    @property
    def n_elements(self) -> int:
        lead = int(np.prod(self._lead_sizes)) if self._lead_sizes else 1
        return lead * self.points_per_field

    def field_nbytes(self) -> int:
        return self.points_per_field * self.dtype.itemsize

    def axis_periods(self) -> dict[str, float]:
        return {"lon": 360.0}


class TransformedDatacube(Datacube):
    """Logical view of a regular :class:`TensorDatacube` through axis
    transforms (DESIGN.md §2.5).

    The slicer plans entirely in **logical** coordinates — it only ever
    sees the transformed axes via :meth:`axis`/:meth:`next_axis` — while
    :meth:`base_offset`/:meth:`leaf_offsets` resolve logical paths back
    to **storage** coordinates, so ``ExtractionPlan`` offsets address
    the untransformed flat storage byte-for-byte.  This is what keeps
    the paper's exact-byte guarantee when the index space stops being a
    regular lattice: the transform layer moves the irregularity into the
    lookup, not into the plan.

    Each transform consumes one or two *consecutive* storage axes and
    replaces them, in place, with its logical axis; untouched axes pass
    through under their own names.
    """

    def __init__(self, base: TensorDatacube, transforms: Sequence[Transform]):
        self.base = base
        self.dtype = base.dtype
        by_first = {t.storage_names[0]: t for t in transforms}
        if len(by_first) != len(transforms):
            raise ValueError("transforms consume overlapping storage axes")
        base_names = base.axis_names
        names: list[str] = []
        consumed: set[str] = set()
        self._transforms: dict[str, Transform] = {}
        for i, n in enumerate(base_names):
            if n in consumed:
                continue
            t = by_first.get(n)
            if t is None:
                names.append(n)
                continue
            k = len(t.storage_names)
            if tuple(base_names[i:i + k]) != t.storage_names:
                raise ValueError(
                    f"transform {t.logical_name}: storage axes "
                    f"{t.storage_names} must be consecutive in the base "
                    f"cube's natural order {base_names}")
            names.append(t.logical_name)
            consumed.update(t.storage_names)
            self._transforms[t.logical_name] = t
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate logical axis names: {names}")
        self._logical_names = tuple(names)
        self._axes: dict[str, Axis] = {}
        for nm in names:
            t = self._transforms.get(nm)
            if t is None:
                self._axes[nm] = base.axis(nm, {})
            else:
                self._axes[nm] = t.logical_axis(
                    [base.axis(s, {}) for s in t.storage_names])

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self._logical_names

    def next_axis(self, path: Mapping[str, int]) -> str | None:
        for n in self._logical_names:
            if n not in path:
                return n
        return None

    def axis(self, name: str, path: Mapping[str, int]) -> Axis:
        return self._axes[name]

    # -- logical → storage resolution -------------------------------------
    def _storage_path(self, path: Mapping[str, int]) -> dict[str, int]:
        sp: dict[str, int] = {}
        for n, p in path.items():
            t = self._transforms.get(n)
            if t is None:
                sp[n] = p
            else:
                cols = t.storage_positions(np.asarray([p], np.int64))
                for s, col in zip(t.storage_names, cols):
                    sp[s] = int(col[0])
        return sp

    def base_offset(self, path: Mapping[str, int]) -> int:
        return self.base.base_offset(self._storage_path(path))

    def leaf_offsets(self, path: Mapping[str, int],
                     positions: np.ndarray) -> np.ndarray:
        """Vectorised logical→storage offsets for a leaf block on the
        deepest logical axis — the vector-leaf fast path stays intact
        under transforms (a merged storage-minor pair keeps logical runs
        byte-contiguous by construction)."""
        off = self.base.base_offset(self._storage_path(path))
        leaf = self.next_axis(path)
        pos = np.asarray(positions, np.int64)
        t = self._transforms.get(leaf)
        if t is None:
            return off + pos * self.base.stride(leaf)
        out = np.full(len(pos), off, np.int64)
        for s, col in zip(t.storage_names, t.storage_positions(pos)):
            out += col * self.base.stride(s)
        return out

    def logical_stride(self, name: str) -> int:
        """Flat-offset increment per +1 step of logical position on
        ``name``.  Exists (and is constant) for every transform kind:
        plain and single-storage transforms (cyclic, mapped) map
        positions identically, so the storage stride carries over; a
        merged pair's logical position ``p`` resolves to
        ``maj_stride·(p // n_minor) + min_stride·(p % n_minor)`` which,
        because the pair is consecutive in the base cube's row-major
        order (``maj_stride == n_minor·min_stride``), collapses to
        ``min_stride·p``."""
        t = self._transforms.get(name)
        if t is None:
            return self.base.stride(name)
        return self.base.stride(t.storage_names[-1])

    @property
    def n_elements(self) -> int:
        return self.base.n_elements

    def axis_periods(self) -> dict[str, float]:
        out = dict(self.base.axis_periods())
        for t in self._transforms.values():
            for s in t.storage_names:
                out.pop(s, None)
            if t.period is not None:
                out[t.logical_name] = t.period
        return out


class BranchingDatacube(Datacube):
    """Leading categorical axis selecting heterogeneous child cubes
    (paper Fig. 2)."""

    def __init__(self, axis_name: str, children: Mapping[Any, Datacube],
                 dtype=np.float64):
        self._axis_name = axis_name
        self._labels = list(children.keys())
        self._children = [children[k] for k in self._labels]
        self._axis = CategoricalAxis(axis_name, self._labels)
        self.dtype = np.dtype(dtype)
        sizes = [c.n_elements for c in self._children]
        self._bases = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def next_axis(self, path: Mapping[str, int]) -> str | None:
        if self._axis_name not in path:
            return self._axis_name
        child = self._children[path[self._axis_name]]
        sub = {k: v for k, v in path.items() if k != self._axis_name}
        return child.next_axis(sub)

    def axis(self, name: str, path: Mapping[str, int]) -> Axis:
        if name == self._axis_name:
            return self._axis
        child = self._children[path[self._axis_name]]
        sub = {k: v for k, v in path.items() if k != self._axis_name}
        return child.axis(name, sub)

    def base_offset(self, path: Mapping[str, int]) -> int:
        k = path[self._axis_name]
        child = self._children[k]
        sub = {n: v for n, v in path.items() if n != self._axis_name}
        return int(self._bases[k]) + child.base_offset(sub)

    @property
    def n_elements(self) -> int:
        return int(self._bases[-1])

    def axis_periods(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for c in self._children:
            out.update(c.axis_periods())
        return out
