"""Interface levels (paper §4.1).

* low level   — :class:`ConvexPolytope`: raw vertex lists.
* high level  — :class:`Box`, :class:`Disk`, :class:`Ellipsoid`,
  :class:`Polygon` (concave OK — ear-clipped into convex triangles),
  :class:`Span`, :class:`Point`, :class:`Select`, :class:`All`, plus the
  constructive ops :class:`Union` and :class:`Path` (sweep along a
  polyline — the paper's flight-path request).
* domain level — built on these in ``repro.dataplane`` (country
  extraction, time-series, vertical profiles, MRI vessels).

Every shape decomposes into convex low-level polytopes
(``.polytopes()``) and/or categorical selections (``.selects()``); the
slicer only ever sees those two primitives — "the building blocks of all
possible Polytope requests".
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from .geometry import Polytope, box_polytope, regular_polygon
from .hull import convex_hull_prune

# Quantum for canonical-form coordinate quantization (DESIGN.md §4).
# Matches the order of geometry.PLANE_TOL: two vertices closer than this
# land on the same grid cell and hash identically — datacube index
# spacing is always far coarser, so colliding requests select the same
# bytes.
CANON_TOL = 1e-9


def _quantize(arr: np.ndarray, tol: float) -> np.ndarray:
    """Snap coordinates to a grid of size ``tol`` (normalising -0.0).

    Grid snapping is inherently unstable at cell boundaries: two values
    within ``tol`` of each other can straddle a cell midpoint and land
    in adjacent cells (e.g. ``0.49·tol`` → cell 0, ``0.51·tol`` → cell
    1), so sub-tolerance-equal requests are *usually*, not *always*,
    assigned the same canonical key (pinned by the straddle regression
    test in tests/test_plan_cache.py).  Exact-match cache consumers
    tolerate this — a straddled key is only a spurious cold plan — and
    the neighborhood index recovers it: straddled anchors differ by one
    quantum, which resolves to a zero-step drift and reuses the parent
    plan (see ``repro.core.delta_planner``).
    """
    q = np.round(np.asarray(arr, np.float64) / tol) * tol
    return q + 0.0


def _canon_value(v: Any, tol: float,
                 period: float | None = None) -> tuple[str, str]:
    """Order-stable key for a Select value of any hashable type."""
    if isinstance(v, (bool, str, bytes)):
        return (type(v).__name__, repr(v))
    if isinstance(v, (int, float, np.integer, np.floating)):
        # ints and equal floats must collide (axis.find treats 5 == 5.0)
        q = float(_quantize(np.array(float(v)), tol))
        if period:
            # cyclic axis: canonical representative in [0, period)
            q = float(_quantize(np.array(q - np.floor(q / period) * period),
                                tol))
        return ("f", repr(q))
    return (type(v).__name__, repr(v))


def _canon_points(p: Polytope, tol: float,
                  periods: "dict[str, float] | None") -> np.ndarray:
    """Quantized vertex array, shifted to the canonical period window.

    On each cyclic axis the polytope is translated by a whole number of
    periods so its *minimum* coordinate lands in ``[0, period)`` —
    seam-straddling requests shifted by whole periods therefore share
    one representative (and one plan-cache key), while the straddle
    itself (vertices above the period) is preserved exactly.
    """
    pts = _quantize(p.points, tol)
    if periods:
        for j, ax in enumerate(p.axes):
            period = periods.get(ax)
            if period:
                k = np.floor(pts[:, j].min() / period)
                if k:
                    pts[:, j] = _quantize(pts[:, j] - k * period, tol)
    return pts


def canonical_key(polys: Sequence[Polytope], selects: Sequence["Select"],
                  tol: float = CANON_TOL,
                  periods: "dict[str, float] | None" = None) -> tuple:
    """Canonical form of a (polytopes, selects) decomposition.

    Order-insensitive: union members and selects are sorted sets, select
    values are merged per axis (the slicer unions them anyway), and
    vertex coordinates are quantized to ``tol`` so float noise below the
    index spacing cannot split equivalent requests.  Exact duplicates
    (repeated union members, repeated select values) collapse — they
    produce the same plan.

    ``periods`` (axis → period, from ``Datacube.axis_periods``) folds
    cyclic axes: each polytope/select value is shifted by whole periods
    onto a canonical window, so period-shifted and seam-straddling
    spellings of the same request collide (DESIGN.md §2.5).
    """
    poly_keys: set[tuple] = set()
    for p in polys:
        pts = _canon_points(p, tol, periods)
        rows = tuple(sorted(set(map(tuple, pts.tolist()))))
        poly_keys.add((tuple(p.axes), rows))
    sel_vals: dict[str, set] = {}
    for s in selects:
        bucket = sel_vals.setdefault(s.axis, set())
        period = periods.get(s.axis) if periods else None
        for v in s.values:
            bucket.add(_canon_value(v, tol, period))
    sel_keys = tuple(sorted(
        (ax, tuple(sorted(vals))) for ax, vals in sel_vals.items()))
    return (tuple(sorted(poly_keys)), sel_keys)


def canonical_hash(polys: Sequence[Polytope], selects: Sequence["Select"],
                   tol: float = CANON_TOL,
                   periods: "dict[str, float] | None" = None) -> str:
    """Stable content hash of :func:`canonical_key` (process-independent:
    sha256 over the repr of nested tuples of strings/floats)."""
    key = canonical_key(polys, selects, tol, periods)
    return hashlib.sha256(repr(key).encode()).hexdigest()


def _is_numeric(v: Any) -> bool:
    """Numeric select values participate in translation (drift); bools,
    strings and other labels do not."""
    return (isinstance(v, (int, float, np.integer, np.floating))
            and not isinstance(v, bool))


def shape_signature(polys: Sequence[Polytope], selects: Sequence["Select"],
                    tol: float = CANON_TOL) -> tuple[tuple,
                                                     dict[str, float]]:
    """Translation-invariant signature of a primitive decomposition.

    The signature is the canonical form quotiented by per-axis
    translation: every vertex coordinate and numeric select value is
    expressed relative to the request's per-axis *anchor* (the minimum
    coordinate seen on that axis), then quantized exactly like
    :func:`canonical_key`.  Two requests that are translates of each
    other — the same flight corridor advanced one timestep, the same
    country crop for the next forecast cycle — therefore share a
    signature while their anchors differ by the drift vector.  The
    neighborhood index (DESIGN.md §8) keys on the signature hash and
    stores anchors separately, so a drifted request resolves to its
    parent plan and only the drift delta remains to be applied.

    No period folding is applied: translation by a whole period *is* a
    translation, so seam-shifted spellings already share a signature
    (their anchors differ by the period, which the delta planner reduces
    modulo the axis length).

    Returns ``(signature_key, anchor)`` with ``anchor`` holding the raw
    (unquantized) per-axis minima — the delta planner needs exact floats
    to recover integer index steps; quantization noise is absorbed by
    its integer-step tolerance.
    """
    anchor: dict[str, float] = {}
    for p in polys:
        for j, ax in enumerate(p.axes):
            m = float(p.points[:, j].min())
            anchor[ax] = min(anchor.get(ax, m), m)
    for s in selects:
        for v in s.values:
            if _is_numeric(v):
                f = float(v)
                anchor[s.axis] = min(anchor.get(s.axis, f), f)

    poly_keys: set[tuple] = set()
    for p in polys:
        a = np.array([anchor[ax] for ax in p.axes], np.float64)
        pts = _quantize(p.points - a, tol)
        rows = tuple(sorted(set(map(tuple, pts.tolist()))))
        poly_keys.add((tuple(p.axes), rows))
    sel_vals: dict[str, set] = {}
    for s in selects:
        bucket = sel_vals.setdefault(s.axis, set())
        for v in s.values:
            if _is_numeric(v):
                q = float(_quantize(np.array(float(v) - anchor[s.axis]),
                                    tol))
                bucket.add(("f", repr(q)))
            else:
                bucket.add(_canon_value(v, tol))
    sel_keys = tuple(sorted(
        (ax, tuple(sorted(vals))) for ax, vals in sel_vals.items()))
    return (tuple(sorted(poly_keys)), sel_keys), anchor


def signature_hash(polys: Sequence[Polytope], selects: Sequence["Select"],
                   tol: float = CANON_TOL) -> tuple[str, dict[str, float]]:
    """Stable sha256 of :func:`shape_signature`'s key, plus the anchor."""
    key, anchor = shape_signature(polys, selects, tol)
    return hashlib.sha256(repr(key).encode()).hexdigest(), anchor


class Shape:
    def polytopes(self) -> list[Polytope]:
        return []

    def selects(self) -> list["Select"]:
        return []

    def canonical_key(self, tol: float = CANON_TOL,
                      periods: dict[str, float] | None = None) -> tuple:
        """Canonical form of this shape's primitive decomposition."""
        return canonical_key(self.polytopes(), self.selects(), tol, periods)

    def canonical_hash(self, tol: float = CANON_TOL,
                       periods: dict[str, float] | None = None) -> str:
        return canonical_hash(self.polytopes(), self.selects(), tol, periods)


@dataclass
class Select(Shape):
    """Specific index values — the only legal query on categorical axes;
    also usable on ordered axes (snaps to nearest index)."""

    axis: str
    values: Sequence[Any]

    def selects(self) -> list["Select"]:
        return [self]


@dataclass
class All(Shape):
    """Everything on an axis (an unconstrained axis behaves the same)."""

    axis: str

    def polytopes(self) -> list[Polytope]:
        big = 1e30
        return [Polytope((self.axis,), np.array([[-big], [big]]))]


@dataclass
class Span(Shape):
    """1-D interval on an ordered axis."""

    axis: str
    lo: float
    hi: float

    def polytopes(self) -> list[Polytope]:
        return [Polytope((self.axis,), np.array([[self.lo], [self.hi]],
                                                np.float64))]


@dataclass
class Point(Shape):
    """Exact point on ordered axes (degenerate polytope)."""

    axes: Sequence[str]
    coords: Sequence[float]

    def polytopes(self) -> list[Polytope]:
        return [Polytope(tuple(self.axes),
                         np.asarray([self.coords], np.float64))]


@dataclass
class Box(Shape):
    axes: Sequence[str]
    lows: Sequence[float]
    highs: Sequence[float]

    def polytopes(self) -> list[Polytope]:
        return [box_polytope(self.axes, self.lows, self.highs)]


@dataclass
class ConvexPolytope(Shape):
    """Low-level interface: explicit convex vertex list."""

    axes: Sequence[str]
    vertices: np.ndarray

    def polytopes(self) -> list[Polytope]:
        return [Polytope(tuple(self.axes), np.asarray(self.vertices,
                                                      np.float64))]


@dataclass
class Disk(Shape):
    """2-D disk, approximated by a regular n-gon (convex, slicer-exact)."""

    axes: Sequence[str]
    center: Sequence[float]
    radius: float | Sequence[float]
    segments: int = 32

    def polytopes(self) -> list[Polytope]:
        r = self.radius
        rx, ry = (r, r) if np.isscalar(r) else r
        ang = 2 * np.pi * np.arange(self.segments) / self.segments
        cx, cy = self.center
        pts = np.stack([cx + rx * np.cos(ang), cy + ry * np.sin(ang)], -1)
        return [Polytope(tuple(self.axes), pts)]


@dataclass
class Ellipsoid(Shape):
    """3-D ellipsoid approximated by a convex point shell."""

    axes: Sequence[str]
    center: Sequence[float]
    radii: Sequence[float]
    rings: int = 8
    segments: int = 16

    def polytopes(self) -> list[Polytope]:
        cx, cy, cz = self.center
        rx, ry, rz = self.radii
        pts = []
        for i in range(1, self.rings):
            phi = np.pi * i / self.rings
            for j in range(self.segments):
                th = 2 * np.pi * j / self.segments
                pts.append([cx + rx * np.sin(phi) * np.cos(th),
                            cy + ry * np.sin(phi) * np.sin(th),
                            cz + rz * np.cos(phi)])
        pts.append([cx, cy, cz + rz])
        pts.append([cx, cy, cz - rz])
        return [Polytope(tuple(self.axes), np.asarray(pts))]


@dataclass
class Polygon(Shape):
    """Simple (possibly concave) 2-D polygon → convex triangles via
    ear clipping.  This is how country shapes enter the slicer; the
    paper's interface "is responsible for decomposing all user request
    shapes into these base convex polytopes"."""

    axes: Sequence[str]
    points: np.ndarray  # (N, 2) boundary, any winding, not self-crossing

    def polytopes(self) -> list[Polytope]:
        tris = ear_clip(np.asarray(self.points, np.float64))
        return [Polytope(tuple(self.axes), t, label="tri") for t in tris]


@dataclass
class Union(Shape):
    """Union of sub-shapes on the same axes (paper Fig 8c)."""

    shapes: Sequence[Shape]

    def polytopes(self) -> list[Polytope]:
        return [p for s in self.shapes for p in s.polytopes()]

    def selects(self) -> list[Select]:
        return [q for s in self.shapes for q in s.selects()]


@dataclass
class Path(Shape):
    """Sweep a convex base shape along a polyline (flight path, MRI
    vessel centreline).  Each segment's sweep is the convex hull of the
    base placed at both endpoints — convex per segment, union overall."""

    axes: Sequence[str]
    base: Shape                     # shape on a subset/all of `axes`
    waypoints: np.ndarray           # (K, len(axes)) polyline vertices

    def polytopes(self) -> list[Polytope]:
        wps = np.asarray(self.waypoints, np.float64)
        base_polys = self.base.polytopes()
        out = []
        for bp in base_polys:
            # embed base vertices into the full axis space (zero-padded on
            # axes the base does not constrain)
            D = len(self.axes)
            emb = np.zeros((bp.n_vertices, D))
            for j, ax in enumerate(bp.axes):
                emb[:, self.axes.index(ax)] = bp.points[:, j]
            for a, b in zip(wps[:-1], wps[1:]):
                seg = np.concatenate([emb + a, emb + b], axis=0)
                seg = convex_hull_prune(seg)
                out.append(Polytope(tuple(self.axes), seg, label="sweep"))
        return out


@dataclass
class Request:
    """A full query: shapes over disjoint axis sets; their product is the
    requested region.  Uncovered axes default to All."""

    shapes: Sequence[Shape]

    def polytopes(self) -> list[Polytope]:
        """Primitive decomposition, memoized per Request object.

        Triangulating a concave polygon (ear-clipping) dominates the
        cost and the decomposition is consumed repeatedly — canonical
        hash, shape signature, extent probes and the slicer all start
        here.  Mutating ``shapes`` after the first call is not
        supported (the same contract as :meth:`canonical_hash`).
        Callers must not mutate the returned list.
        """
        polys = self.__dict__.get("_polytopes")
        if polys is None:
            polys = [p for s in self.shapes for p in s.polytopes()]
            self.__dict__["_polytopes"] = polys
        return polys

    def selects(self) -> list[Select]:
        return [q for s in self.shapes for q in s.selects()]

    def covered_axes(self) -> set[str]:
        axes: set[str] = set()
        for p in self.polytopes():
            axes |= set(p.axes)
        for s in self.selects():
            axes.add(s.axis)
        return axes

    def canonical_form(self, tol: float = CANON_TOL,
                       periods: dict[str, float] | None = None) -> tuple:
        """Order-insensitive, tolerance-quantized canonical form.

        Two requests with equal canonical forms select the same datacube
        bytes (same primitive decomposition up to member order, select
        order/duplication, and sub-``tol`` coordinate noise), so their
        extraction plans are interchangeable — the plan cache's key
        (DESIGN.md §4).  With ``periods`` (from
        ``Datacube.axis_periods``) requests on cyclic axes are
        additionally normalized modulo the period, so seam-straddling
        requests shifted by whole periods collide too (DESIGN.md §2.5).
        """
        return canonical_key(self.polytopes(), self.selects(), tol, periods)

    def canonical_hash(self, tol: float = CANON_TOL,
                       periods: dict[str, float] | None = None) -> str:
        """Stable sha256 content hash of :meth:`canonical_form`.

        Memoized per Request object (decomposition — e.g. ear-clipping a
        country polygon — dominates the hash cost; a served request is
        hashed exactly once).  Mutating ``shapes`` after the first call
        is not supported.
        """
        pkey = tuple(sorted(periods.items())) if periods else ()
        cache = self.__dict__.setdefault("_canon_hashes", {})
        h = cache.get((tol, pkey))
        if h is None:
            h = canonical_hash(self.polytopes(), self.selects(), tol,
                               periods)
            cache[(tol, pkey)] = h
        return h

    def shape_signature(self, tol: float = CANON_TOL
                        ) -> tuple[str, dict[str, float]]:
        """Translation-invariant signature hash + per-axis anchor.

        Memoized like :meth:`canonical_hash` (the decomposition
        dominates); the anchor dict is shared, callers must not mutate
        it.  Drifted requests share the hash; their anchors differ by
        the drift vector (see :func:`shape_signature`).
        """
        cache = self.__dict__.setdefault("_sig_cache", {})
        out = cache.get(tol)
        if out is None:
            out = signature_hash(self.polytopes(), self.selects(), tol)
            cache[tol] = out
        return out


# ---------------------------------------------------------------------------
def ear_clip(poly: np.ndarray) -> list[np.ndarray]:
    """Triangulate a simple polygon (ear clipping, O(n^2))."""
    pts = list(range(len(poly)))
    if len(pts) < 3:
        raise ValueError("polygon needs >= 3 points")
    # enforce CCW
    if _signed_area(poly) < 0:
        pts = pts[::-1]
    tris: list[np.ndarray] = []
    guard = 0
    while len(pts) > 3 and guard < 10 * len(poly) ** 2:
        guard += 1
        n = len(pts)
        clipped = False
        for i in range(n):
            a, b, c = pts[(i - 1) % n], pts[i], pts[(i + 1) % n]
            pa, pb, pc = poly[a], poly[b], poly[c]
            if _cross(pb - pa, pc - pb) <= 1e-14:   # reflex or degenerate
                continue
            tri = np.array([pa, pb, pc])
            if any(_point_in_tri(poly[q], tri) for q in pts
                   if q not in (a, b, c)):
                continue
            tris.append(tri)
            pts.pop(i)
            clipped = True
            break
        if not clipped:     # numerically stuck: emit fan and stop
            break
    if len(pts) >= 3:
        anchor = pts[0]
        for i in range(1, len(pts) - 1):
            tris.append(np.array([poly[anchor], poly[pts[i]],
                                  poly[pts[i + 1]]]))
    return tris


def _signed_area(poly: np.ndarray) -> float:
    x, y = poly[:, 0], poly[:, 1]
    return 0.5 * float(np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y))


def _cross(u: np.ndarray, v: np.ndarray) -> float:
    return float(u[0] * v[1] - u[1] * v[0])


def _point_in_tri(p: np.ndarray, tri: np.ndarray) -> bool:
    a, b, c = tri
    d1 = _cross(b - a, p - a)
    d2 = _cross(c - b, p - b)
    d3 = _cross(a - c, p - c)
    neg = (d1 < -1e-14) or (d2 < -1e-14) or (d3 < -1e-14)
    pos = (d1 > 1e-14) or (d2 > 1e-14) or (d3 > 1e-14)
    return not (neg and pos)
