"""Device-resident planner: Algorithm 1 as one fused pipeline invocation.

``DevicePlanner`` is the host adapter around ``repro.kernels.plan``: it
resolves the *leading* axes of a request exactly the way ``Slicer``
does (selects, implicit Alls, 1-D spans — cheap python over small
axes), then hands every (leading-path × trailing-polytope) job to the
fused pipeline, which runs the expensive trailing-2-D stage — row
discovery, per-row slicing, column ranges, run emission — in a single
device invocation instead of a host round-trip per BFS layer.

Parity contract: the emitted plan is byte-identical to the host
planner's (``Slicer(fast_paths=False)`` per-index reference, and the
default fast-path planner wherever the two agree) — every comparison
and interpolation in the pipeline mirrors the host formulas
operation-for-operation, and the pipeline runs in float64 by default
(``jax.experimental.enable_x64``; pass ``dtype=np.float32`` for the
TPU-native approximate mode).  ``SliceStats`` accounting (§5.2) is
reproduced exactly: dim-2 slices = candidate rows, dim-1 slices =
leading span indices + emitted leaf points pre-dedupe.

Device plans carry ``coords={}``: the gather path consumes offsets and
runs only, and skipping per-point coordinate labels is part of why the
device path is fast.  Callers needing labelled points use the host
planner.

``plan()`` returns ``None`` whenever the request or cube falls outside
the pipeline's shape (non-trailing 2-D polytopes, cyclic major axis,
non-contiguous minor storage, duplicate frontier positions, > 2³¹
elements, fan-out past ``max_jobs``) — the ``Slicer`` entry point then
falls back to the host path transparently, the same opt-out contract as
``fast_paths``.
"""

from __future__ import annotations

import itertools
import time
from typing import Any

import numpy as np

from .axes import CategoricalAxis, CyclicAxis, OrderedAxis
from .datacube import Datacube, TensorDatacube, TransformedDatacube
from .geometry import PLANE_TOL
from .index_tree import ExtractionPlan, coalesce_runs
from .shapes import Request
from .slicer import SliceStats

I32_LIMIT = 2 ** 31
MAX_JOBS = 4096
LOOKUP_TOL = 1e-9   # OrderedAxis.indices_in_range default tol


def _lookup_eps(ax: OrderedAxis) -> float:
    sv = ax._sorted
    return LOOKUP_TOL * max(abs(float(sv[0])), abs(float(sv[-1])), 1.0)


def _row_count(sv0: np.ndarray, eps0: float, poly, major: str) -> int:
    lo, hi = poly.extents(major)
    i0 = int(np.searchsorted(sv0, lo - eps0, side="left"))
    i1 = int(np.searchsorted(sv0, hi + eps0, side="right"))
    return max(i1 - i0, 0)


class DevicePlanner:
    """Fused-pipeline planner with transparent host fallback."""

    def __init__(self, datacube: Datacube, use_pallas: bool = False,
                 interpret: bool = True, dtype=np.float64,
                 max_jobs: int = MAX_JOBS):
        self.datacube = datacube
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.dtype = np.dtype(dtype)
        self.max_jobs = max_jobs
        self._grid: dict[str, Any] | None | bool = False  # False = unprobed

    # -- cube eligibility (static, cached) --------------------------------
    def _prepare_grid(self) -> dict[str, Any] | None:
        dc = self.datacube
        # Only cubes whose axis walk is path-independent: the octahedral
        # and branching cubes interleave axis *shape* with the path, so
        # the fixed (n0, n1) trailing lattice does not exist for them.
        if not isinstance(dc, (TensorDatacube, TransformedDatacube)):
            return None
        if dc.n_elements >= I32_LIMIT:
            return None   # run starts must fit the i32 plan buffer
        names = dc.axis_names
        if len(names) < 2:
            return None
        axes = {n: dc.axis(n, {}) for n in names}
        major, minor = names[-2], names[-1]
        ax0, ax1 = axes[major], axes[minor]
        # Cyclic major would need the two-segment wrap per *row block*,
        # not per row — host planner handles it; we fall back.
        if not isinstance(ax0, OrderedAxis) or isinstance(ax0, CyclicAxis):
            return None
        if not isinstance(ax1, OrderedAxis) or ax1._order is not None:
            return None

        # Minor axis must be unit-stride and identity-ordered in storage
        # so column ranges are byte runs.
        n1 = len(ax1)
        if isinstance(dc, TensorDatacube):
            if dc.stride(minor) != 1:
                return None
        else:
            t1 = dc._transforms.get(minor)
            sname = minor if t1 is None else t1.storage_names[-1]
            if t1 is not None:
                if len(t1.storage_names) != 1:
                    return None
                probe = np.arange(n1, dtype=np.int64)
                cols = t1.storage_positions(probe)
                if len(cols) != 1 or not np.array_equal(cols[0], probe):
                    return None
            if dc.base.stride(sname) != 1:
                return None

        # Per-sorted-row storage offsets through permutation + transform.
        n0 = len(ax0)
        perm0 = (ax0._order.astype(np.int64) if ax0._order is not None
                 else np.arange(n0, dtype=np.int64))
        if isinstance(dc, TensorDatacube):
            rowoff = perm0 * dc.stride(major)
        else:
            t0 = dc._transforms.get(major)
            if t0 is None:
                rowoff = perm0 * dc.base.stride(major)
            else:
                rowoff = np.zeros(n0, np.int64)
                cols = t0.storage_positions(perm0)
                for s, col in zip(t0.storage_names, cols):
                    rowoff += col.astype(np.int64) * dc.base.stride(s)

        return {
            "lead": names[:-2], "major": major, "minor": minor,
            "axes": axes,
            "sv0": np.asarray(ax0._sorted, np.float64),
            "sv1": np.asarray(ax1._sorted, np.float64),
            "rowoff": rowoff, "n0": n0, "n1": n1,
            "eps0": _lookup_eps(ax0), "eps1": _lookup_eps(ax1),
            "cyclic": isinstance(ax1, CyclicAxis),
            "period": float(ax1.period) if isinstance(ax1, CyclicAxis)
            else 0.0,
        }

    # -- request eligibility + leading-axis resolution --------------------
    def _resolve_leading(self, g: dict[str, Any], request: Request):
        """Mirror Slicer's leading-axis expansion; None = fall back.

        Returns (levels, dim1_lead, empty): ``levels`` is the per-axis
        position list in BFS order, ``dim1_lead`` the host planner's
        dim-1 slice count for leading 1-D spans (multiplied by the
        frontier fan-in at that depth), ``empty`` flags a dead frontier.
        """
        polys = list(request.polytopes())
        selects = list(request.selects())
        polys2 = [p for p in polys if p.ndim == 2]
        if not polys2:
            return None
        for p in polys2:
            if set(p.axes) != {g["major"], g["minor"]}:
                return None
        lead = g["lead"]
        for s in selects:
            if s.axis not in lead:
                return None
        for p in polys:
            if p.ndim == 2:
                continue
            if p.ndim != 1 or p.axes[0] not in lead:
                return None

        levels: list[tuple[str, list[int]]] = []
        dim1_lead = 0
        n_items = 1
        empty = False
        for name in lead:
            ax = g["axes"][name]
            sels = [s for s in selects if s.axis == name]
            pls = [p for p in polys if p.ndim == 1 and p.axes[0] == name]
            # One constraint per leading axis: several (or a select AND
            # a span) make the host enqueue overlapping frontier items
            # whose union/stat semantics we don't replicate.
            if len(sels) + len(pls) > 1:
                return None
            if isinstance(ax, CategoricalAxis):
                if pls:
                    return None
                if sels:
                    pos, seen = [], set()
                    for v in sels[0].values:
                        p_ = ax.find(v)
                        if p_ is not None and p_ not in seen:
                            seen.add(p_)
                            pos.append(int(p_))
                else:
                    pos = list(range(len(ax)))
            elif isinstance(ax, OrderedAxis):
                if sels:
                    pos = [int(ax.nearest(ax.to_float(v))[0])
                           for v in sels[0].values]
                    if len(set(pos)) != len(pos):
                        return None   # duplicate frontier items
                elif pls:
                    lo, hi = pls[0].extents(name)
                    parr, _ = ax.indices_in_range(lo, hi)
                    pos = [int(x) for x in parr]
                    dim1_lead += n_items * len(pos)
                else:
                    pos = list(range(len(ax)))
            else:
                return None
            if not pos:
                empty = True
                break
            levels.append((name, pos))
            n_items *= len(pos)
        if not empty and n_items * len(polys2) > self.max_jobs:
            return None
        return levels, polys2, dim1_lead, empty

    # -- planning ----------------------------------------------------------
    def plan(self, request: Request
             ) -> tuple[ExtractionPlan, SliceStats] | None:
        t_start = time.perf_counter()
        if self._grid is False:
            self._grid = self._prepare_grid()
        g = self._grid
        if g is None:
            return None
        resolved = self._resolve_leading(g, request)
        if resolved is None:
            return None
        levels, polys2, dim1_lead, empty = resolved
        dc = self.datacube
        itemsize = dc.dtype.itemsize

        if empty:
            return self._finish(np.empty(0, np.int64), 0, dim1_lead, 0.0,
                                t_start, itemsize)

        # Static row budget: the widest major-index range over the
        # polytopes (identical for every leading path), padded for lanes.
        max_rows = max(_row_count(g["sv0"], g["eps0"], p, g["major"])
                       for p in polys2)
        if max_rows == 0:
            return self._finish(np.empty(0, np.int64), 0, dim1_lead, 0.0,
                                t_start, itemsize)
        max_rows = -(-max_rows // 8) * 8

        # Pack jobs: (leading path × polytope).
        names = [n for n, _ in levels]
        paths = [dict(zip(names, combo))
                 for combo in itertools.product(*(p for _, p in levels))]
        vmax = max(p.n_vertices for p in polys2)
        j_n = len(paths) * len(polys2)
        verts = np.zeros((j_n, vmax, 2), self.dtype)
        valid = np.zeros((j_n, vmax), bool)
        bases = np.zeros(j_n, np.int64)
        j = 0
        for path in paths:
            b = dc.base_offset(path)
            for p in polys2:
                k0 = p.axes.index(g["major"])
                k1 = p.axes.index(g["minor"])
                nv = p.n_vertices
                verts[j, :nv, 0] = p.points[:, k0]
                verts[j, :nv, 1] = p.points[:, k1]
                valid[j, :nv] = True
                bases[j] = b
                j += 1

        scalars = np.array([g["eps0"], g["eps1"], PLANE_TOL, g["period"]],
                           self.dtype)
        t_pipe = time.perf_counter()
        starts, lens, meta = self._invoke(verts, valid, bases, scalars,
                                          g, max_rows)
        pipe_dt = time.perf_counter() - t_pipe

        n_runs, n_rows, n_pts = (int(meta[0]), int(meta[1]), int(meta[2]))
        run_starts = starts[:n_runs].astype(np.int64)
        run_lens = lens[:n_runs].astype(np.int64)
        # Expand runs → offsets, dedupe across jobs (union members /
        # cyclic seam overlap), re-coalesce into sorted burst runs — the
        # same canonical form `flatten` emits.
        ends = np.cumsum(run_lens)
        total = int(ends[-1]) if n_runs else 0
        offsets = (np.repeat(run_starts, run_lens)
                   + np.arange(total, dtype=np.int64)
                   - np.repeat(ends - run_lens, run_lens))
        offsets = np.unique(offsets)
        return self._finish(offsets, n_rows, dim1_lead + n_pts, pipe_dt,
                            t_start, itemsize)

    def _invoke(self, verts, valid, bases, scalars, g, max_rows):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.kernels._casting import checked_cast_i32
        from repro.kernels.plan import ops as plan_ops

        n_el = self.datacube.n_elements

        def run():
            starts, lens, meta = plan_ops.plan_runs_2d(
                jnp.asarray(verts), jnp.asarray(valid),
                checked_cast_i32(jnp.asarray(bases),
                                 what="device planner base offsets",
                                 n_elements=n_el),
                jnp.asarray(g["sv0"], verts.dtype),
                checked_cast_i32(jnp.asarray(g["rowoff"]),
                                 what="device planner row offsets",
                                 n_elements=n_el),
                jnp.asarray(g["sv1"], verts.dtype),
                jnp.asarray(scalars),
                n0=g["n0"], n1=g["n1"], max_rows=max_rows,
                cyclic=g["cyclic"], use_pallas=self.use_pallas,
                interpret=self.interpret)
            return (np.asarray(starts), np.asarray(lens),
                    np.asarray(meta))

        if self.dtype == np.float64:
            with enable_x64():
                return run()
        return run()

    def _finish(self, offsets, n_rows, n_dim1, pipe_dt, t_start, itemsize):
        run_starts, run_lens = coalesce_runs(offsets)
        plan = ExtractionPlan(offsets=offsets, run_starts=run_starts,
                              run_lengths=run_lens, coords={},
                              itemsize=itemsize)
        stats = SliceStats()
        if n_rows:
            stats.record_slices(2, n_rows, 0.0)
        if n_dim1:
            stats.record_slices(1, n_dim1, 0.0)
        stats.n_points = len(offsets)
        stats.slicing_time_s = pipe_dt
        stats.total_time_s = time.perf_counter() - t_start
        return plan, stats
