"""Polytope geometry: the paper's §3.2 slicing step.

A polytope is the convex hull of a vertex set (paper §2).  We keep the
vertex representation throughout — slicing with the hyperplane
``axis = value`` is: split vertices by sign, linearly interpolate every
(below, above) pair onto the plane, keep on-plane vertices, then prune
interior points with a convex hull (QuickHull, paper §3.2 "Slicing
Step") so the vertex count does not grow quadratically slice after
slice.

Geometry planning runs on the host in float64 (exactness matters — a
vertex a hair inside/outside a plane changes which bytes are read).
The batched, on-device variant of the same math lives in
``repro.kernels.slice``.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .hull import convex_hull_prune

# Tolerance for "vertex lies on the slicing plane".  The paper notes
# datacube indices always have gaps; 1e-9 of the axis scale is far below
# any real index spacing.
PLANE_TOL = 1e-9


@dataclass
class Polytope:
    """Convex polytope given by vertices, tagged with the axes it spans.

    ``axes``   — names of the datacube axes this polytope is defined on,
                 in datacube order (paper: "find polytopes defined on
                 axis").
    ``points`` — (V, D) float64 vertex array, D == len(axes).
    ``is_box`` — axis-aligned box fast path: slicing a box yields the
                 box without interpolation or hull pruning (the paper's
                 "performs the exact same orthogonal extractions … in
                 minimal time", made structural).
    """

    axes: tuple[str, ...]
    points: np.ndarray
    # Book-keeping for union-of-shapes provenance (paper Fig 8c).
    label: str = ""
    is_box: bool = False

    def __post_init__(self):
        self.points = np.asarray(self.points, dtype=np.float64)
        if self.points.ndim == 1:
            self.points = self.points[:, None]
        if isinstance(self.axes, list):
            self.axes = tuple(self.axes)
        if self.points.ndim != 2 or self.points.shape[1] != len(self.axes):
            raise ValueError(
                f"points {self.points.shape} inconsistent with axes {self.axes}"
            )
        # Paper Algorithm 1 line 2: "Remove duplicate points in polytopes".
        self.points = _dedupe(self.points)

    @property
    def ndim(self) -> int:
        return len(self.axes)

    @property
    def n_vertices(self) -> int:
        return len(self.points)

    def extents(self, axis: str) -> tuple[float, float]:
        """Min/max of the polytope along ``axis`` (Algorithm 1 line 6)."""
        k = self.axes.index(axis)
        col = self.points[:, k]
        return float(col.min()), float(col.max())

    def axis_position(self, axis: str) -> int:
        return self.axes.index(axis)

    def slice_at(self, axis: str, value: float) -> "Polytope | None":
        """Intersect with hyperplane ``axis == value``; drop that axis.

        Returns the lower-dimensional polytope on the remaining axes, or
        ``None`` when the plane misses the polytope.  This is the paper's
        §3.2 "Slicing Step" verbatim: sign split → pairwise interpolation
        → hull prune.
        """
        k = self.axes.index(axis)
        rest = tuple(a for a in self.axes if a != axis)
        if self.is_box:
            lo, hi = self.extents(axis)
            tol = PLANE_TOL * max(1.0, abs(lo), abs(hi))
            if value < lo - tol or value > hi + tol:
                return None
            if rest:
                keep = [i for i in range(len(self.axes)) if i != k]
                pts = _dedupe(self.points[:, keep])
                return Polytope(rest, pts, label=self.label,
                                is_box=True)
            return Polytope((), np.zeros((1, 0)), label=self.label)
        pts = slice_vertices(self.points, k, value)
        if pts is None:
            return None
        if rest:
            pts = convex_hull_prune(pts)
            return Polytope(rest, pts, label=self.label)
        # 0-dimensional leaf: the plane hit the final axis.
        return Polytope((), np.zeros((1, 0)), label=self.label)

    def translate(self, offset: Sequence[float]) -> "Polytope":
        return Polytope(self.axes, self.points + np.asarray(offset, np.float64),
                        label=self.label)

    def contains(self, point: Sequence[float], tol: float = 1e-9) -> bool:
        """Exact membership test (oracle for tests; not used by the slicer).

        A point is in the convex hull iff it is a convex combination of
        vertices — solved as a small LP via scipy.
        """
        from scipy.optimize import linprog

        pt = np.asarray(point, np.float64)
        V = self.points
        n = len(V)
        # minimize 0 s.t. V^T w = pt, sum w = 1, w >= 0
        A_eq = np.vstack([V.T, np.ones((1, n))])
        b_eq = np.concatenate([pt, [1.0]])
        res = linprog(np.zeros(n), A_eq=A_eq, b_eq=b_eq,
                      bounds=[(0, None)] * n, method="highs")
        if res.status == 0:
            return True
        # LP infeasibility is exact up to solver tol; retry with slack for
        # boundary points.
        if tol > 0:
            lo = pt - tol
            hi = pt + tol
            A_ub = np.vstack([V.T, -V.T])
            b_ub = np.concatenate([hi, -lo])
            res = linprog(np.zeros(n), A_ub=A_ub, b_ub=b_ub,
                          A_eq=np.ones((1, n)), b_eq=[1.0],
                          bounds=[(0, None)] * n, method="highs")
            return res.status == 0
        return False


def _dedupe(points: np.ndarray, tol: float = 0.0) -> np.ndarray:
    """Remove duplicate vertices (Algorithm 1 line 2)."""
    if len(points) <= 1:
        return points
    if tol == 0.0:
        return np.unique(points, axis=0)
    # Tolerance-aware dedupe: round to a grid of size tol.
    keys = np.round(points / tol).astype(np.int64)
    _, idx = np.unique(keys, axis=0, return_index=True)
    return points[np.sort(idx)]


def slice_vertices(points: np.ndarray, k: int, value: float,
                   tol: float = PLANE_TOL) -> np.ndarray | None:
    """Core slicing math on a raw (V, D) vertex array.

    Returns the (V', D-1) intersection vertices (axis ``k`` removed), or
    ``None`` if the hyperplane misses the polytope.  Vectorised over all
    (below × above) vertex pairs — this is the exact routine the Pallas
    ``slice`` kernel batches over many polytopes.
    """
    col = points[:, k]
    scale = max(1.0, np.abs(col).max())
    d = col - value
    on = np.abs(d) <= tol * scale
    below = d < -tol * scale
    above = d > tol * scale

    if points.shape[1] == 1:
        # 1-D polytope: the slice is a 0-D point iff the plane hits it.
        if on.any() or (below.any() and above.any()):
            return np.zeros((1, 0))
        return None

    keep = np.delete(points, k, axis=1)
    out = [keep[on]] if on.any() else []

    if below.any() and above.any():
        lo_pts, lo_d = points[below], d[below]
        hi_pts, hi_d = points[above], d[above]
        # t over all pairs: t_ij = d_lo_i / (d_lo_i - d_hi_j)  in (0, 1)
        t = lo_d[:, None] / (lo_d[:, None] - hi_d[None, :])
        lo_keep = np.delete(lo_pts, k, axis=1)
        hi_keep = np.delete(hi_pts, k, axis=1)
        interp = lo_keep[:, None, :] + t[..., None] * (
            hi_keep[None, :, :] - lo_keep[:, None, :])
        out.append(interp.reshape(-1, points.shape[1] - 1))
    if not out:
        return None
    pts = np.concatenate(out, axis=0)
    if len(pts) == 0:
        return None
    return _dedupe(pts)


def box_polytope(axes: Sequence[str], lows: Sequence[float],
                 highs: Sequence[float]) -> Polytope:
    """Axis-aligned box as a polytope (2^D corners)."""
    lows = np.asarray(lows, np.float64)
    highs = np.asarray(highs, np.float64)
    corners = np.array(list(itertools.product(*zip(lows, highs))))
    return Polytope(tuple(axes), corners, is_box=True)


def simplex_polytope(axes: Sequence[str], vertices: np.ndarray) -> Polytope:
    return Polytope(tuple(axes), vertices)


def regular_polygon(axes: Sequence[str], center: Sequence[float],
                    radius: float, n: int = 16,
                    phase: float = 0.0) -> Polytope:
    """Regular n-gon — the paper's Disk high-level shape is a polygon
    approximation of a circle (convex, so exact for the slicer)."""
    if len(axes) != 2:
        raise ValueError("regular_polygon is 2D")
    ang = phase + 2 * np.pi * np.arange(n) / n
    cx, cy = center
    pts = np.stack([cx + radius * np.cos(ang), cy + radius * np.sin(ang)], -1)
    return Polytope(tuple(axes), pts)
