"""The slicer — paper Algorithm 1.

Breadth-first over the datacube's natural axis order: per axis, find the
polytopes defined on it, read their extents, look up the discrete
indices inside the extents, add those indices to the index tree, then
slice each polytope at each index to obtain the child polytopes for the
next layer.

Faithful points:
 * BFS (FIFO frontier) — paper: "breadth-first (layer by layer) …
   ensures the algorithm does not lose track of what values inside the
   requested polytopes have already been found".
 * Categorical axes: existence check only, no slicing (paper §3.2).
 * Union requests are sliced sub-shape by sub-shape and merged in the
   index tree (paper Fig 8c measures exactly this cost).
 * Slice counting for the §5.2 bound  N_slices ≤ Σ_i Π_{j≤i} n_j.

Beyond the paper (host-side perf, see DESIGN.md §3):
 * vectorised index lookup (searchsorted, not per-index scans);
 * the final ordered axis emits **vector leaf blocks** instead of one
   node + one 1-D slice object per index — the 1-D slices the paper
   shows dominate runtime collapse into one numpy range query.

Coordinate frames (DESIGN.md §2.5): Algorithm 1 runs entirely in
**logical** coordinates — the axes the datacube presents, which for a
``TransformedDatacube`` may be cyclic (seam-straddling ranges split into
canonical in-period sub-intervals by ``CyclicAxis``), merged, or mapped.
The *positions* those axes return are already the datacube's own index
space, and ``ExtractionPlan`` offsets are resolved by the datacube in
**storage** coordinates; the slicer never converts between the two.
Both fast paths survive transforms unchanged: vector leaves delegate to
``Datacube.leaf_offsets`` (which vectorises the logical→storage map) and
shared-box slicing only touches logical geometry.

``fast_paths=False`` disables the vector-leaf and shared-box fast paths
so every index walks the per-index slicing path — the reference
executor for the fast-path parity differential suite
(tests/test_fastpath_parity.py); production callers never set it.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

from .axes import CategoricalAxis, OrderedAxis
from .datacube import Datacube
from .geometry import Polytope
from .index_tree import ExtractionPlan, IndexNode, flatten
from .shapes import Request, Select


@dataclass
class SliceStats:
    """Instrumentation for the paper's §5 analysis."""

    n_slices: int = 0                      # polytope/hyperplane cuts
    n_slices_by_dim: dict[int, int] = field(default_factory=dict)
    n_points: int = 0
    slicing_time_s: float = 0.0            # time in slice_at only
    total_time_s: float = 0.0              # full Algorithm-1 walltime

    def record_slice(self, dim: int, dt: float) -> None:
        self.record_slices(dim, 1, dt)

    def record_slices(self, dim: int, n: int, dt: float) -> None:
        """Bulk recorder — the single entry point for every slicing path
        (per-index, shared-box, vector-leaf), so ``n_slices`` and
        ``n_slices_by_dim`` always agree and the §5.2 bound
        ``N_slices ≤ Σ_i Π_{j≤i} n_j`` holds by construction."""
        self.n_slices += n
        self.n_slices_by_dim[dim] = self.n_slices_by_dim.get(dim, 0) + n
        self.slicing_time_s += dt


@dataclass
class _Item:
    """Frontier entry: a partially-assigned subtree."""

    node: IndexNode
    path: dict[str, int]
    polys: list[Polytope]
    selects: list[Select]


class Slicer:
    """Algorithm 1 executor over any :class:`Datacube`."""

    def __init__(self, datacube: Datacube, fast_paths: bool = True,
                 verify: bool = False, device_planner: bool = False):
        self.datacube = datacube
        self.fast_paths = fast_paths
        # verify=True runs the static plan checker
        # (repro.analysis.plan_check) over every emitted plan and raises
        # on any violated invariant — the runtime hook of DESIGN.md §6.
        self.verify = verify
        # device_planner=True routes eligible requests through the fused
        # on-device pipeline (repro.core.device_planner), which emits
        # byte-identical plans in one invocation instead of a host
        # round-trip per BFS layer; ineligible requests fall back to the
        # host path below transparently.  Same opt-out contract as
        # fast_paths.  Pass a DevicePlanner instance to configure the
        # backend (use_pallas / dtype / job cap).
        self._device_planner = None
        if device_planner:
            if device_planner is True:
                from .device_planner import DevicePlanner

                self._device_planner = DevicePlanner(datacube)
            else:
                self._device_planner = device_planner

    def build_index_tree(self, request: Request,
                         lead_filter: "frozenset[int] | set[int] | None"
                         = None) -> tuple[IndexNode, SliceStats]:
        """Run Algorithm 1; with ``lead_filter`` the *root* (leading
        axis) expansion is restricted to those storage positions, so the
        delta planner (core/delta_planner.py) can re-slice exactly the
        leading-axis slabs whose intersections changed under a drift and
        splice the rest arithmetically.  Deeper levels are unaffected —
        a filtered run is byte-identical to the matching slabs of the
        unfiltered tree."""
        t0 = time.perf_counter()
        stats = SliceStats()
        root = IndexNode()
        polys = list(request.polytopes())
        selects = list(request.selects())
        frontier: deque[_Item] = deque(
            [_Item(node=root, path={}, polys=polys, selects=selects)])

        while frontier:
            item = frontier.popleft()
            axis_name = self.datacube.next_axis(item.path)
            if axis_name is None:
                item.node.complete = True
                continue
            axis = self.datacube.axis(axis_name, item.path)
            pos_filter = lead_filter if not item.path else None
            if isinstance(axis, CategoricalAxis):
                self._expand_categorical(item, axis_name, axis, frontier)
            else:
                self._expand_ordered(item, axis_name, axis, frontier,
                                     stats, pos_filter=pos_filter)

        stats.n_points = root.n_points()
        stats.total_time_s = time.perf_counter() - t0
        return root, stats

    def extract_plan(self, request: Request) -> tuple[ExtractionPlan, SliceStats]:
        if self._device_planner is not None:
            out = self._device_planner.plan(request)
            if out is not None:
                plan, stats = out
                if self.verify:
                    from repro.analysis.plan_check import verify_plan

                    verify_plan(plan, datacube=self.datacube, stats=stats)
                return plan, stats
            # fall through: request/cube outside the pipeline's shape
        t0 = time.perf_counter()
        root, stats = self.build_index_tree(request)
        plan = flatten(root, self.datacube)
        stats.total_time_s = time.perf_counter() - t0
        if self.verify:
            # Lazy import: analysis is dependency-light but optional on
            # the hot path; the checker is duck-typed so no cycle forms.
            from repro.analysis.plan_check import verify_plan

            verify_plan(plan, datacube=self.datacube, stats=stats)
        return plan, stats

    # -- categorical axes --------------------------------------------------
    def _expand_categorical(self, item: _Item, axis_name: str,
                            axis: CategoricalAxis,
                            frontier: deque) -> None:
        mine = [s for s in item.selects if s.axis == axis_name]
        rest = [s for s in item.selects if s.axis != axis_name]
        if not mine:
            # Implicit All — every label (paper: existence check only).
            wanted = list(enumerate(axis.values))
        else:
            # Dedupe by position: the same label twice (within or across
            # Selects) must enqueue ONE frontier item — duplicates would
            # expand the whole subtree below this node twice (the index
            # tree merges them, so the plan was right but the work and
            # slice counts silently doubled).
            wanted = []
            seen: set[int] = set()
            for sel in mine:
                for v in sel.values:
                    pos = axis.find(v)
                    if pos is not None and pos not in seen:
                        # (absent labels are silently skipped)
                        seen.add(pos)
                        wanted.append((pos, v))
        for pos, v in wanted:
            child = item.node.child(axis_name, pos, v)
            frontier.append(_Item(node=child,
                                  path={**item.path, axis_name: pos},
                                  polys=item.polys, selects=rest))

    # -- ordered axes --------------------------------------------------------
    def _expand_ordered(self, item: _Item, axis_name: str,
                        axis: OrderedAxis, frontier: deque,
                        stats: SliceStats,
                        pos_filter: "frozenset[int] | set[int] | None"
                        = None) -> None:
        mine = [p for p in item.polys if axis_name in p.axes]
        rest = [p for p in item.polys if axis_name not in p.axes]
        sel_mine = [s for s in item.selects if s.axis == axis_name]
        sel_rest = [s for s in item.selects if s.axis != axis_name]

        def narrowed(pos: np.ndarray, vals: np.ndarray):
            if pos_filter is None:
                return pos, vals
            keep = np.fromiter((int(p) in pos_filter for p in pos),
                               bool, count=len(pos))
            return pos[keep], vals[keep]

        if not mine and not sel_mine:
            # Implicit All over an ordered axis.
            pos, vals = narrowed(np.arange(len(axis)), axis.values)
            self._emit(item, axis_name, pos, vals, None, rest, sel_rest,
                       frontier, stats)
            return

        for sel in sel_mine:
            # Point selections on an ordered axis: snap to nearest index.
            pos_list, val_list = [], []
            for v in sel.values:
                p, val = axis.nearest(axis.to_float(v))
                pos_list.append(p)
                val_list.append(val)
            pos, vals = narrowed(np.asarray(pos_list, np.int64),
                                 np.asarray(val_list))
            self._emit(item, axis_name, pos, vals, None, rest, sel_rest,
                       frontier, stats)

        for poly in mine:
            # Union semantics (paper Fig 8c): each union member is sliced
            # independently; results merge in the shared children dict.
            lo, hi = poly.extents(axis_name)           # Alg.1 line 6
            pos, vals = axis.indices_in_range(lo, hi)  # Alg.1 line 7
            pos, vals = narrowed(pos, vals)
            self._emit(item, axis_name, pos, vals, poly, rest, sel_rest,
                       frontier, stats)

    def _emit(self, item: _Item, axis_name: str, pos: np.ndarray,
              vals: np.ndarray, poly: Polytope | None,
              other_polys: list[Polytope], selects: list[Select],
              frontier: deque, stats: SliceStats) -> None:
        if len(pos) == 0:
            return
        remaining_after = self.datacube.next_axis(
            {**item.path, axis_name: int(pos[0])})
        is_last_axis = remaining_after is None
        poly_dim = 0 if poly is None else poly.ndim

        if (self.fast_paths and is_last_axis and not other_polys
                and not selects and poly_dim <= 1):
            # Vector leaf fast path: these are the paper's 1-D slices —
            # emitted as one array block (counted, not materialised).
            item.node.add_leaf_block(axis_name, pos, vals)
            if poly is not None:
                stats.record_slices(1, len(pos), 0.0)
            return

        # Axis-aligned boxes slice to the same sub-box at every index
        # inside their extent — compute it once and share (turns O(points)
        # box slicing into O(nodes); boxes match the bbox baseline cost).
        # Count only when the shared slice exists: if the probe misses
        # (probe value pushed outside the box by the index-lookup
        # tolerance), the per-index path below does — and counts — the
        # slicing instead.
        shared_box = None
        if (self.fast_paths and poly is not None and poly.is_box
                and poly.ndim > 1):
            t0 = time.perf_counter()
            shared_box = poly.slice_at(axis_name,
                                       float(vals[len(vals) // 2]))
            if shared_box is not None:
                stats.record_slices(poly.ndim, len(pos),
                                    time.perf_counter() - t0)

        for p_, v_ in zip(pos, vals):
            child_polys = list(other_polys)
            if shared_box is not None:
                child_polys.append(shared_box)
            elif poly is not None and poly.ndim > 1:
                t0 = time.perf_counter()
                sub = poly.slice_at(axis_name, float(v_))   # Alg.1 line 12
                stats.record_slice(poly.ndim, time.perf_counter() - t0)
                if sub is None:
                    continue
                child_polys.append(sub)
            elif poly is not None:
                # 1-D polytope consumed by selecting this index.
                stats.record_slice(1, 0.0)
            child = item.node.child(axis_name, int(p_), float(v_))
            frontier.append(_Item(node=child,
                                  path={**item.path, axis_name: int(p_)},
                                  polys=child_polys, selects=selects))
