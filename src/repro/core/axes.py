"""Datacube axes (paper §3.1).

Two families:

* **Ordered axes** — comparable, interpolatable indices (floats, ints,
  datetimes).  Range queries are meaningful; the slicer slices along
  them.  Subclasses capture "special behaviours" the paper mentions —
  cyclicity (longitude) being the important one.
* **Categorical axes** — discrete labels.  Only point queries; the
  slicer merely checks existence (paper: "as would happen in every other
  traditional extraction algorithm").

Index lookup is vectorised ``searchsorted`` — this is the "more
efficient datacube look-up mechanism" the paper flags as future work
after measuring XArray lookup dominating total runtime (§5.1, Fig 8a).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import numpy as np


class Axis:
    """Base axis: a named, discrete set of indices."""

    name: str
    is_ordered: bool = False

    def __len__(self) -> int:  # pragma: no cover - abstract
        raise NotImplementedError


class OrderedAxis(Axis):
    """Ordered axis over sorted float-convertible indices.

    ``values`` may be irregular and sparse (paper: "indices on ordered
    axes do not have to be uniformly spaced").  Datetimes are supported
    via ``transform``/``untransform`` hooks mapping to float64 (seconds
    since epoch) — the slicer works in the transformed space, satisfying
    the paper's "measurable and linear" assumption.
    """

    is_ordered = True

    def __init__(self, name: str, values: Sequence[Any]):
        self.name = name
        self._raw = list(values)
        vals = self._to_float(np.asarray(values))
        order = np.argsort(vals, kind="stable")
        if not np.all(order[:-1] < order[1:]):
            # keep a stable position map back into storage order
            self._order = order
        else:
            self._order = None
        self._sorted = vals[order] if self._order is not None else vals
        if np.any(np.diff(self._sorted) < 0):
            raise ValueError(f"axis {name}: could not sort values")

    @staticmethod
    def _to_float(arr: np.ndarray) -> np.ndarray:
        if np.issubdtype(arr.dtype, np.datetime64):
            return arr.astype("datetime64[s]").astype(np.float64)
        return arr.astype(np.float64)

    def __len__(self) -> int:
        return len(self._sorted)

    @property
    def values(self) -> np.ndarray:
        """Axis index values in storage order, as float64."""
        if self._order is None:
            return self._sorted
        out = np.empty_like(self._sorted)
        out[self._order] = self._sorted
        return out

    def to_float(self, value: Any) -> float:
        return float(self._to_float(np.asarray([value]))[0])

    # -- range query ----------------------------------------------------
    def indices_in_range(self, lo: float, hi: float,
                         tol: float = 1e-9) -> tuple[np.ndarray, np.ndarray]:
        """Positions (storage order) and float values inside [lo, hi].

        ``tol`` (relative to axis span) widens the interval so that
        polytope vertices that lie *exactly* on an index value are always
        captured despite float roundoff.
        """
        span = max(abs(self._sorted[0]), abs(self._sorted[-1]), 1.0)
        eps = tol * span
        i0 = int(np.searchsorted(self._sorted, lo - eps, side="left"))
        i1 = int(np.searchsorted(self._sorted, hi + eps, side="right"))
        pos = np.arange(i0, i1)
        vals = self._sorted[i0:i1]
        if self._order is not None:
            pos = self._order[i0:i1]
        return pos, vals

    def nearest(self, value: float) -> tuple[int, float]:
        i = int(np.clip(np.searchsorted(self._sorted, value), 1,
                        len(self._sorted) - 1))
        j = i if abs(self._sorted[i] - value) < abs(
            self._sorted[i - 1] - value) else i - 1
        pos = int(self._order[j]) if self._order is not None else j
        return pos, float(self._sorted[j])


class CyclicAxis(OrderedAxis):
    """Ordered axis with period ``period`` (e.g. longitude, period 360).

    Queries may cross the wrap point; ``indices_in_range`` splits the
    unwrapped query interval into in-period segments and concatenates
    results, returning *unwrapped* values so that interpolation in the
    polytope's coordinate frame stays linear (paper §3.1 "cyclicity …
    special subclasses").
    """

    def __init__(self, name: str, values: Sequence[Any], period: float):
        super().__init__(name, values)
        self.period = float(period)
        base = self._sorted
        if base[-1] - base[0] >= self.period:
            raise ValueError("axis values must span < one period")

    def indices_in_range(self, lo: float, hi: float,
                         tol: float = 1e-9) -> tuple[np.ndarray, np.ndarray]:
        if hi - lo >= self.period:  # whole circle requested
            pos = np.arange(len(self._sorted))
            if self._order is not None:
                pos = self._order[pos.astype(np.int64)]
            return pos, self._sorted.copy()
        # Shift the stored window onto the query's unwrapped frame.
        out_pos, out_val = [], []
        base_lo = self._sorted[0]
        # candidate shifts k*period placing stored values inside [lo, hi]
        k0 = int(np.floor((lo - self._sorted[-1]) / self.period))
        k1 = int(np.ceil((hi - base_lo) / self.period))
        for k in range(k0, k1 + 1):
            shift = k * self.period
            p, v = super().indices_in_range(lo - shift, hi - shift, tol)
            if len(p):
                out_pos.append(p)
                out_val.append(v + shift)
        if not out_pos:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        pos = np.concatenate(out_pos)
        val = np.concatenate(out_val)
        # A vertex exactly on the wrap point can appear twice; dedupe by pos
        # keeping first (values differ by the period — same storage cell).
        _, first = np.unique(pos, return_index=True)
        first.sort()
        return pos[first], val[first]


class CategoricalAxis(Axis):
    """Unordered axis of distinct labels (paper: string indices etc.)."""

    is_ordered = False

    def __init__(self, name: str, values: Sequence[Any]):
        self.name = name
        self._values = list(values)
        self._lookup = {v: i for i, v in enumerate(self._values)}
        if len(self._lookup) != len(self._values):
            raise ValueError(f"axis {name}: duplicate categorical labels")

    def __len__(self) -> int:
        return len(self._values)

    @property
    def values(self) -> list:
        return list(self._values)

    def find(self, value: Any) -> int | None:
        """Position of ``value`` or None (paper: existence check only)."""
        return self._lookup.get(value)
