"""Datacube axes (paper §3.1) and axis transforms (DESIGN.md §2.5).

Two axis families:

* **Ordered axes** — comparable, interpolatable indices (floats, ints,
  datetimes).  Range queries are meaningful; the slicer slices along
  them.  Subclasses capture "special behaviours" the paper mentions —
  cyclicity (longitude) being the important one.
* **Categorical axes** — discrete labels.  Only point queries; the
  slicer merely checks existence (paper: "as would happen in every other
  traditional extraction algorithm").

Index lookup is vectorised ``searchsorted`` — this is the "more
efficient datacube look-up mechanism" the paper flags as future work
after measuring XArray lookup dominating total runtime (§5.1, Fig 8a).

**Axis transforms** generalize the index space beyond regular lattices
(the production datacube shapes of *Beyond Standard Datacubes*): a
:class:`Transform` presents one or more *storage* axes of a regular
cube as a single *logical* axis the slicer plans against — cyclic
(longitude wrap), merged (date+time → datetime), and mapped (monotone
value→index for reduced/Gaussian grids).  ``TransformedDatacube``
(core/datacube.py) owns the logical↔storage translation; transforms
only describe it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import numpy as np


class Axis:
    """Base axis: a named, discrete set of indices."""

    name: str
    is_ordered: bool = False

    def __len__(self) -> int:  # pragma: no cover - abstract
        raise NotImplementedError


class OrderedAxis(Axis):
    """Ordered axis over sorted float-convertible indices.

    ``values`` may be irregular and sparse (paper: "indices on ordered
    axes do not have to be uniformly spaced").  Datetimes are supported
    via ``transform``/``untransform`` hooks mapping to float64 (seconds
    since epoch) — the slicer works in the transformed space, satisfying
    the paper's "measurable and linear" assumption.
    """

    is_ordered = True

    def __init__(self, name: str, values: Sequence[Any]):
        self.name = name
        self._raw = list(values)
        vals = self._to_float(np.asarray(values))
        order = np.argsort(vals, kind="stable")
        if not np.all(order[:-1] < order[1:]):
            # keep a stable position map back into storage order
            self._order = order
        else:
            self._order = None
        self._sorted = vals[order] if self._order is not None else vals
        if np.any(np.diff(self._sorted) < 0):
            raise ValueError(f"axis {name}: could not sort values")

    @staticmethod
    def _to_float(arr: np.ndarray) -> np.ndarray:
        if np.issubdtype(arr.dtype, np.datetime64):
            return arr.astype("datetime64[s]").astype(np.float64)
        return arr.astype(np.float64)

    def __len__(self) -> int:
        return len(self._sorted)

    @property
    def values(self) -> np.ndarray:
        """Axis index values in storage order, as float64."""
        if self._order is None:
            return self._sorted
        out = np.empty_like(self._sorted)
        out[self._order] = self._sorted
        return out

    @property
    def is_storage_sorted(self) -> bool:
        """True iff storage order equals ascending value order — the
        precondition for positional index arithmetic (a shift of ``s``
        index steps moves every storage position by exactly ``s``),
        which the delta planner relies on."""
        return self._order is None

    def to_float(self, value: Any) -> float:
        return float(self._to_float(np.asarray([value]))[0])

    # -- range query ----------------------------------------------------
    def indices_in_range(self, lo: float, hi: float,
                         tol: float = 1e-9) -> tuple[np.ndarray, np.ndarray]:
        """Positions (storage order) and float values inside [lo, hi].

        ``tol`` (relative to axis span) widens the interval so that
        polytope vertices that lie *exactly* on an index value are always
        captured despite float roundoff.
        """
        span = max(abs(self._sorted[0]), abs(self._sorted[-1]), 1.0)
        eps = tol * span
        i0 = int(np.searchsorted(self._sorted, lo - eps, side="left"))
        i1 = int(np.searchsorted(self._sorted, hi + eps, side="right"))
        pos = np.arange(i0, i1)
        vals = self._sorted[i0:i1]
        if self._order is not None:
            pos = self._order[i0:i1]
        return pos, vals

    def nearest(self, value: float) -> tuple[int, float]:
        i = int(np.clip(np.searchsorted(self._sorted, value), 1,
                        len(self._sorted) - 1))
        j = i if abs(self._sorted[i] - value) < abs(
            self._sorted[i - 1] - value) else i - 1
        pos = int(self._order[j]) if self._order is not None else j
        return pos, float(self._sorted[j])


class CyclicAxis(OrderedAxis):
    """Ordered axis with period ``period`` (e.g. longitude, period 360).

    Queries may cross the wrap point; ``indices_in_range`` splits the
    unwrapped query interval into in-period segments and concatenates
    results, returning *unwrapped* values so that interpolation in the
    polytope's coordinate frame stays linear (paper §3.1 "cyclicity …
    special subclasses").
    """

    def __init__(self, name: str, values: Sequence[Any], period: float):
        super().__init__(name, values)
        self.period = float(period)
        base = self._sorted
        if base[-1] - base[0] >= self.period:
            raise ValueError("axis values must span < one period")

    def indices_in_range(self, lo: float, hi: float,
                         tol: float = 1e-9) -> tuple[np.ndarray, np.ndarray]:
        if hi - lo >= self.period:  # whole circle requested
            pos = np.arange(len(self._sorted))
            if self._order is not None:
                pos = self._order[pos.astype(np.int64)]
            return pos, self._sorted.copy()
        # Shift the stored window onto the query's unwrapped frame.
        out_pos, out_val = [], []
        base_lo = self._sorted[0]
        # candidate shifts k*period placing stored values inside [lo, hi]
        k0 = int(np.floor((lo - self._sorted[-1]) / self.period))
        k1 = int(np.ceil((hi - base_lo) / self.period))
        for k in range(k0, k1 + 1):
            shift = k * self.period
            p, v = super().indices_in_range(lo - shift, hi - shift, tol)
            if len(p):
                out_pos.append(p)
                out_val.append(v + shift)
        if not out_pos:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        pos = np.concatenate(out_pos)
        val = np.concatenate(out_val)
        # A vertex exactly on the wrap point can appear twice; dedupe by pos
        # keeping first (values differ by the period — same storage cell).
        _, first = np.unique(pos, return_index=True)
        first.sort()
        return pos[first], val[first]

    def nearest(self, value: float) -> tuple[int, float]:
        """Nearest index under the cyclic metric: a point just below the
        seam snaps *across* it to the first stored value when that is
        closer (e.g. lon 359.9 → the 0.0 cell, not 359.0)."""
        base = self._sorted
        v = base[0] + (value - base[0]) % self.period
        pos, val = super().nearest(v)
        if abs(base[0] + self.period - v) < abs(val - v):
            pos = int(self._order[0]) if self._order is not None else 0
            val = float(base[0])
        return pos, val


# ---------------------------------------------------------------------------
# Axis transforms (DESIGN.md §2.5)

class Transform:
    """Protocol: present stored axes of a regular cube as one logical axis.

    ``logical_name``   — the axis name the slicer sees and requests use.
    ``storage_names``  — the consumed storage axes, in the base cube's
                         natural order (consecutive).
    ``period``         — set iff the logical axis is cyclic; consumed by
                         request canonicalization (``Datacube.axis_periods``)
                         so seam-equivalent requests share a plan-cache key.

    Logical axis *positions* address the transform's own index space;
    :meth:`storage_positions` maps them back onto each storage axis.  The
    slicer never sees storage coordinates — ``TransformedDatacube``
    applies this mapping when resolving flat offsets.
    """

    logical_name: str
    storage_names: tuple[str, ...]
    period: float | None = None

    def logical_axis(self, storage_axes: Sequence[OrderedAxis]) -> Axis:
        """Build the logical axis from the (already constructed) storage
        axes.  Called once by ``TransformedDatacube``."""
        raise NotImplementedError

    def storage_positions(self, positions: np.ndarray) -> tuple[np.ndarray, ...]:
        """Map logical positions → one position array per storage axis."""
        raise NotImplementedError


class CyclicTransform(Transform):
    """Cyclic wrap (longitude): the stored axis spans less than one
    period; logical requests may straddle the seam and are split into
    canonical in-period sub-intervals by :class:`CyclicAxis`."""

    def __init__(self, name: str, period: float,
                 storage_name: str | None = None):
        self.logical_name = name
        self.storage_names = (storage_name or name,)
        self.period = float(period)

    def logical_axis(self, storage_axes: Sequence[OrderedAxis]) -> Axis:
        (ax,) = storage_axes
        return CyclicAxis(self.logical_name, ax.values, period=self.period)

    def storage_positions(self, positions: np.ndarray) -> tuple[np.ndarray, ...]:
        return (np.asarray(positions, np.int64),)


class MergedTransform(Transform):
    """Two stored axes presented as one logical axis (date+time →
    datetime).

    Logical value at storage ``(i, j)`` is ``major[i] + minor[j]`` (both
    already in a common unit, e.g. seconds); the flattened row-major
    sequence must be strictly increasing, i.e. the major step must
    exceed the minor axis's span.  Logical position ``p`` ↔ storage
    ``(p // n_minor, p % n_minor)`` — when the pair is storage-minor
    this keeps logical leaf runs byte-contiguous.
    """

    def __init__(self, name: str, storage_names: Sequence[str]):
        if len(storage_names) != 2:
            raise ValueError("MergedTransform merges exactly two axes")
        self.logical_name = name
        self.storage_names = tuple(storage_names)
        self.period = None
        self._n_minor: int | None = None

    def logical_axis(self, storage_axes: Sequence[OrderedAxis]) -> Axis:
        major, minor = storage_axes
        vals = (np.asarray(major.values)[:, None] +
                np.asarray(minor.values)[None, :]).ravel()
        if np.any(np.diff(vals) <= 0):
            raise ValueError(
                f"merged axis {self.logical_name}: combined values must be "
                f"strictly increasing (major step must exceed minor span)")
        self._n_minor = len(minor)
        return OrderedAxis(self.logical_name, vals)

    def storage_positions(self, positions: np.ndarray) -> tuple[np.ndarray, ...]:
        if self._n_minor is None:
            raise RuntimeError("logical_axis() must be called first")
        p = np.asarray(positions, np.int64)
        return (p // self._n_minor, p % self._n_minor)


class MappedTransform(Transform):
    """Monotone value→index mapping for irregular spacings — the
    reduced/Gaussian-grid shape: storage holds plain row indices, the
    logical axis carries the physically meaningful (irregularly spaced)
    coordinates.  ``values[i]`` is the logical coordinate of storage
    position ``i`` (monotone either way; ``OrderedAxis`` keeps the
    storage-position map)."""

    def __init__(self, name: str, storage_name: str,
                 values: Sequence[float] | None = None,
                 func: Any | None = None):
        if (values is None) == (func is None):
            raise ValueError("provide exactly one of values/func")
        self.logical_name = name
        self.storage_names = (storage_name,)
        self.period = None
        self._values = None if values is None else np.asarray(values,
                                                              np.float64)
        self._func = func

    def logical_axis(self, storage_axes: Sequence[OrderedAxis]) -> Axis:
        (ax,) = storage_axes
        vals = self._values if self._values is not None else np.asarray(
            self._func(np.arange(len(ax))), np.float64)
        if len(vals) != len(ax):
            raise ValueError(
                f"mapped axis {self.logical_name}: {len(vals)} values for "
                f"{len(ax)} storage positions")
        d = np.diff(vals)
        if not (np.all(d > 0) or np.all(d < 0)):
            raise ValueError(
                f"mapped axis {self.logical_name}: mapping must be "
                f"strictly monotone")
        return OrderedAxis(self.logical_name, vals)

    def storage_positions(self, positions: np.ndarray) -> tuple[np.ndarray, ...]:
        return (np.asarray(positions, np.int64),)


class CategoricalAxis(Axis):
    """Unordered axis of distinct labels (paper: string indices etc.)."""

    is_ordered = False

    def __init__(self, name: str, values: Sequence[Any]):
        self.name = name
        self._values = list(values)
        self._lookup = {v: i for i, v in enumerate(self._values)}
        if len(self._lookup) != len(self._values):
            raise ValueError(f"axis {name}: duplicate categorical labels")

    def __len__(self) -> int:
        return len(self._values)

    @property
    def values(self) -> list:
        return list(self._values)

    def find(self, value: Any) -> int | None:
        """Position of ``value`` or None (paper: existence check only)."""
        return self._lookup.get(value)
