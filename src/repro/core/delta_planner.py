"""Incremental delta planning for drifting polytopes (DESIGN.md §8).

Production request streams *drift*: the same flight corridor advanced
one timestep, the same country crop for the next forecast cycle.  The
exact-match plan cache misses every one of these; the paper's §5
scaling analysis makes the resulting cold re-plan the dominant latency
for small moving selections.  This module turns a cached parent plan
plus an axis-wise integer index translation into the drifted request's
plan without re-running Algorithm 1 over the untouched slabs:

* untouched leading-axis slabs shift **arithmetically** — every flat
  offset moves by ``Σ s_ax · stride_ax`` (position arithmetic modulo
  the axis length on cyclic axes), and coordinate columns are
  recomputed from the axes' stored value arrays so they are bit-exact
  against cold planning;
* leading-axis slabs whose intersection with the request *changed*
  (entered or left the leading window) re-run the slicer, restricted to
  exactly those root positions via ``Slicer.build_index_tree``'s
  ``lead_filter``;
* §5.2 slice statistics splice additively: ``parent − dropped +
  fresh``, with the dropped slabs' counts measured by re-slicing the
  parent request narrowed to them.

The spliced plan goes through the same emission discipline as a cold
one (``index_tree.assemble_plan``: stable sort + run coalescing), so it
is byte-identical to cold planning — offsets, runs, coords, and slice
counts — which the differential suite in
``tests/test_delta_planner.py`` pins.

Eligibility is conservative and every ineligible case returns ``None``
so callers fall back to a cold plan *transparently* (same contract as
the device planner):

* the cube must be regular (``TensorDatacube`` /
  ``TransformedDatacube``) — path-independent axes with known constant
  strides;
* every shifted axis must be ordered, storage-sorted, and uniformly
  spaced, with the anchor delta an integer number of steps within the
  drift radius;
* a shifted cyclic axis must cover the full circle (``n·step ≈
  period``) and the request window must stay below one period, so the
  seam-split index lookup is translation-equivariant;
* a shifted non-cyclic, non-leading axis must keep both the old and
  new request windows strictly interior to the axis value span (no
  boundary clipping — clipping is only handled on the *leading* axis,
  where the fresh/dropped slab machinery absorbs it);
* select values on shifted axes must be numeric (labels don't
  translate).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import numpy as np

from .axes import CyclicAxis, OrderedAxis
from .datacube import Datacube, TensorDatacube, TransformedDatacube
from .index_tree import ExtractionPlan, assemble_plan, flatten
from .shapes import Request, _is_numeric
from .slicer import Slicer, SliceStats

# |delta/step − round(delta/step)| above this is not an integer drift.
STEP_TOL = 1e-6
# Relative spacing deviation above this means the axis is not uniform.
SPACING_TOL = 1e-9


@dataclass(frozen=True)
class _AxisInfo:
    """Per-axis facts the splice arithmetic needs (probed once)."""

    stride: int                 # flat-offset increment per +1 position
    size: int
    step: float                 # uniform ascending value spacing
    scale: float                # max(|v_first|, |v_last|, 1)
    values: np.ndarray          # storage-order (ascending) float64
    cyclic: bool
    period: float               # 0.0 when not cyclic


class DeltaPlanner:
    """Splice a cached plan into its drifted neighbor's plan.

    ``max_steps`` bounds the drift radius: anchor deltas beyond that
    many index steps on any axis are treated as unrelated requests (a
    far-away clone shares no useful slab overlap, and an unbounded
    radius would let one stale parent shadow the whole axis).
    """

    def __init__(self, datacube: Datacube, slicer: Slicer | None = None,
                 max_steps: int = 64):
        self.datacube = datacube
        self.slicer = slicer if slicer is not None else Slicer(datacube)
        self.max_steps = int(max_steps)
        self._info: dict[str, _AxisInfo] = {}
        self._eligible_cube = isinstance(
            datacube, (TensorDatacube, TransformedDatacube))
        if self._eligible_cube:
            for name in datacube.axis_names:
                info = self._probe_axis(name)
                if info is not None:
                    self._info[name] = info

    # -- axis probing ------------------------------------------------------
    def _probe_axis(self, name: str) -> _AxisInfo | None:
        axis = self.datacube.axis(name, {})
        if not isinstance(axis, OrderedAxis) or not axis.is_storage_sorted:
            return None
        vals = axis.values
        n = len(vals)
        if n < 2:
            return None
        step = (float(vals[-1]) - float(vals[0])) / (n - 1)
        scale = max(abs(float(vals[0])), abs(float(vals[-1])), 1.0)
        if step <= 0 or np.max(np.abs(np.diff(vals) - step)) \
                > SPACING_TOL * scale:
            return None
        cyclic = isinstance(axis, CyclicAxis)
        period = 0.0
        if cyclic:
            period = float(axis.period)
            if abs(n * step - period) > STEP_TOL * period:
                # a partial circle clips at the seam like a boundary
                return None
        return _AxisInfo(stride=self.datacube.logical_stride(name),
                         size=n, step=step, scale=scale, values=vals,
                         cyclic=cyclic, period=period)

    # -- drift resolution --------------------------------------------------
    def axis_shifts(self, old_anchor: dict[str, float],
                    new_anchor: dict[str, float]
                    ) -> dict[str, tuple[float, int]] | None:
        """Anchor pair → per-axis ``(value delta, integer steps)``.

        Returns only axes with a nonzero integer shift; an empty dict is
        a pure sub-quantum jitter (the ``_quantize`` straddle case) and
        means the parent plan can be reused as-is.  ``None`` means the
        pair is not a splicable drift (non-uniform/unsorted axis,
        non-integer step ratio, or outside the drift radius).
        """
        if set(old_anchor) != set(new_anchor):
            return None
        shifts: dict[str, tuple[float, int]] = {}
        for ax, old_v in old_anchor.items():
            delta = new_anchor[ax] - old_v
            if delta == 0.0:
                continue
            info = self._info.get(ax)
            if info is None:
                return None
            ratio = delta / info.step
            s = int(round(ratio))
            if abs(ratio - s) > STEP_TOL:
                return None
            if info.cyclic:
                # on a full circle k steps ≡ k mod n: a drift chain that
                # wraps the seam (e.g. +189 of 192 columns) is really a
                # small backward shift — reduce to the minimal magnitude
                # so the drift radius measures actual displacement
                s %= info.size
                if s > info.size // 2:
                    s -= info.size
            if abs(s) > self.max_steps:
                return None
            if s != 0:
                shifts[ax] = (delta, s)
        return shifts

    # -- eligibility (request-dependent part) ------------------------------
    def _request_extent(self, request: Request, ax: str
                        ) -> tuple[float, float]:
        lo, hi = np.inf, -np.inf
        for p in request.polytopes():
            if ax in p.axes:
                pl, ph = p.extents(ax)
                lo, hi = min(lo, pl), max(hi, ph)
        for s in request.selects():
            if s.axis == ax:
                for v in s.values:
                    if _is_numeric(v):
                        lo, hi = min(lo, float(v)), max(hi, float(v))
        return lo, hi

    def _check_shifted_axes(self, request: Request,
                            parent_request: Request,
                            shifts: dict[str, tuple[float, int]],
                            lead_name: str) -> bool:
        for req in (request, parent_request):
            for sel in req.selects():
                if sel.axis in shifts and any(not _is_numeric(v)
                                              for v in sel.values):
                    return False
        for ax in shifts:
            info = self._info[ax]
            lo_o, hi_o = self._request_extent(parent_request, ax)
            lo_n, hi_n = self._request_extent(request, ax)
            if info.cyclic:
                # keep every window under one period minus one step so
                # the seam-split lookup never takes the full-circle (or
                # double-emission) branch, where positions stop
                # translating
                limit = info.period - abs(info.step)
                if hi_o - lo_o >= limit or hi_n - lo_n >= limit:
                    return False
            elif ax != lead_name:
                # interior check: neither window may clip at the axis
                # boundary (2× the index-lookup widening tolerance)
                eps = 2e-9 * info.scale
                if not (lo_o >= info.values[0] + eps
                        and hi_o <= info.values[-1] - eps
                        and lo_n >= info.values[0] + eps
                        and hi_n <= info.values[-1] - eps):
                    return False
        return True

    # -- leading-axis expansion (mirrors Slicer._expand_ordered) -----------
    def _lead_expansion(self, request: Request, lead_name: str
                        ) -> dict[int, float]:
        """Root-level ``position → value`` map, replicating the
        slicer's emission order (selects before polytopes, first value
        wins per position — ``IndexNode.child`` keeps the first)."""
        axis = self.datacube.axis(lead_name, {})
        exp: dict[int, float] = {}
        for sel in request.selects():
            if sel.axis != lead_name:
                continue
            for v in sel.values:
                p, val = axis.nearest(axis.to_float(v))
                exp.setdefault(int(p), float(val))
        for poly in request.polytopes():
            if lead_name not in poly.axes:
                continue
            lo, hi = poly.extents(lead_name)
            pos, vals = axis.indices_in_range(lo, hi)
            for p, v in zip(pos, vals):
                exp.setdefault(int(p), float(v))
        return exp

    # -- splicing ----------------------------------------------------------
    def splice(self, request: Request, parent_request: Request,
               parent_plan: ExtractionPlan, parent_stats: SliceStats,
               shifts: dict[str, tuple[float, int]]
               ) -> tuple[ExtractionPlan, SliceStats] | None:
        """Parent plan + drift → the drifted request's plan, or ``None``
        when any eligibility rule or internal cross-check fails (caller
        plans cold)."""
        t0 = time.perf_counter()
        if not self._eligible_cube or parent_stats is None:
            return None
        if not shifts:
            # pure sub-quantum anchor jitter: below the index-lookup
            # tolerance, so cold planning would reproduce the parent
            # plan bit-for-bit — reuse it
            stats = SliceStats(
                n_slices=parent_stats.n_slices,
                n_slices_by_dim=dict(parent_stats.n_slices_by_dim),
                n_points=parent_stats.n_points,
                total_time_s=time.perf_counter() - t0)
            return parent_plan, stats
        if any(ax not in self._info for ax in shifts):
            return None
        lead_name = self.datacube.axis_names[0]
        if not self._check_shifted_axes(request, parent_request, shifts,
                                        lead_name):
            return None

        s_lead = shifts.get(lead_name, (0.0, 0))[1]
        kept_mask = None
        lead_vals_by_pos: np.ndarray | None = None
        fresh: list[int] = []
        dropped: list[int] = []
        if s_lead:
            corr = self._lead_correspondence(request, parent_request,
                                             shifts[lead_name], lead_name)
            if corr is None:
                return None
            kept_old, lead_vals_by_pos, fresh, dropped = corr
            if len(kept_old) == 0:
                # No leading slab survives the shift: the "splice" would
                # re-slice every new slab AND re-slice every dropped slab
                # for stats — strictly more work than a cold plan.  Not
                # a delta case; let the caller plan cold.
                return None
            info = self._info[lead_name]
            lead_pos = (parent_plan.offsets // info.stride) % info.size
            kept_mask = np.isin(lead_pos, kept_old)

        if kept_mask is None:
            kept_offs = parent_plan.offsets.copy()
            kept_coords = {k: v.copy()
                           for k, v in parent_plan.coords.items()}
        else:
            kept_offs = parent_plan.offsets[kept_mask]
            kept_coords = {k: v[kept_mask]
                           for k, v in parent_plan.coords.items()}
        self._shift_points(kept_offs, kept_coords, shifts, lead_name,
                           lead_vals_by_pos)
        if len(kept_offs) and (kept_offs.min() < 0 or kept_offs.max()
                               >= self.datacube.n_elements):
            return None

        # fresh slabs: slice only the new leading positions; dropped
        # slabs: re-slice the parent request narrowed to them, for the
        # stats subtraction (their points left via kept_mask already)
        empty = (ExtractionPlan(offsets=np.empty(0, np.int64),
                                run_starts=np.empty(0, np.int64),
                                run_lengths=np.empty(0, np.int64),
                                coords={},
                                itemsize=parent_plan.itemsize),
                 SliceStats())
        fplan, fstats = empty
        if fresh:
            froot, fstats = self.slicer.build_index_tree(
                request, lead_filter=frozenset(fresh))
            fplan = flatten(froot, self.datacube)
        dstats = SliceStats()
        if dropped:
            _, dstats = self.slicer.build_index_tree(
                parent_request, lead_filter=frozenset(dropped))

        stats = self._splice_stats(parent_stats, dstats, fstats)
        if stats is None:
            return None
        # conservation cross-check: points kept must equal parent minus
        # the dropped slabs' points — any mismatch means a slab failed
        # to translate cleanly, so refuse rather than emit a wrong plan
        if len(kept_offs) != parent_plan.n_points - dstats.n_points:
            return None
        if stats.n_points != len(kept_offs) + fplan.n_points:
            return None

        offs = np.concatenate([kept_offs, fplan.offsets])
        if len(offs) == 0:
            coords: dict[str, np.ndarray] = {}
        elif fplan.n_points == 0:
            coords = kept_coords
        elif len(kept_offs) == 0:
            coords = dict(fplan.coords)
        else:
            if set(kept_coords) != set(fplan.coords):
                return None
            coords = {k: np.concatenate([kept_coords[k], fplan.coords[k]])
                      for k in kept_coords}
        plan = assemble_plan(offs, coords, parent_plan.itemsize)
        if plan.n_points != stats.n_points:
            return None
        stats.total_time_s = time.perf_counter() - t0
        return plan, stats

    def _lead_correspondence(
            self, request: Request, parent_request: Request,
            shift: tuple[float, int], lead_name: str
    ) -> "tuple[np.ndarray, np.ndarray, list[int], list[int]] | None":
        """Classify leading-axis slabs: kept (old position array), the
        new-position → value lookup for kept coords, fresh new
        positions, dropped old positions.  ``None`` when old and new
        expansions fail the value-correspondence check (the drift is
        not a clean translation at the root)."""
        delta, s = shift
        info = self._info[lead_name]
        old_exp = self._lead_expansion(parent_request, lead_name)
        new_exp = self._lead_expansion(request, lead_name)
        tol_v = max(STEP_TOL * abs(info.step), SPACING_TOL * info.scale)
        n = info.size
        kept_old: list[int] = []
        fresh: list[int] = []
        dropped: list[int] = []
        vals_by_pos = np.full(n, np.nan)
        for p, v_new in new_exp.items():
            vals_by_pos[p] = v_new
            q = (p - s) % n if info.cyclic else p - s
            v_old = old_exp.get(q)
            if v_old is None:
                fresh.append(p)
                continue
            diff = v_new - (v_old + delta)
            if info.cyclic and info.period:
                # a seam-wrapping drift reduces s mod the circle, so the
                # raw anchor delta can be off by whole periods here
                diff -= round(diff / info.period) * info.period
            if abs(diff) > tol_v:
                return None
            kept_old.append(q)
        for q in old_exp:
            p = (q + s) % n if info.cyclic else q + s
            if p not in new_exp:
                dropped.append(q)
        return (np.asarray(kept_old, np.int64), vals_by_pos, fresh,
                dropped)

    def _shift_points(self, offs: np.ndarray,
                      coords: dict[str, np.ndarray],
                      shifts: dict[str, tuple[float, int]],
                      lead_name: str,
                      lead_vals_by_pos: np.ndarray | None) -> None:
        """Apply the drift to kept points in place: integer offset
        arithmetic per shifted axis, coords recomputed from the axes'
        stored values so they are bit-exact against cold planning.

        Valid because the layout is a mixed-radix number system (the
        regular-cube eligibility): position on axis ``ax`` is
        ``(off // stride) % size`` and per-axis digit updates never
        carry — non-cyclic shifts stay in range by the interior /
        correspondence checks, cyclic shifts wrap within the digit.
        """
        if len(offs) == 0:
            return
        for ax, (delta, s) in shifts.items():
            info = self._info[ax]
            pos = (offs // info.stride) % info.size
            if info.cyclic:
                newpos = (pos + s) % info.size
                offs += (newpos - pos) * info.stride
            else:
                newpos = pos + s
                offs += s * info.stride
            if ax not in coords:
                continue
            if ax == lead_name and lead_vals_by_pos is not None:
                # exact value the cold tree assigns this root slab
                coords[ax] = lead_vals_by_pos[newpos]
            elif info.cyclic:
                # recover the unwrapped frame: the true new value is
                # old + delta up to float fuzz, and cold emits
                # stored[newpos] + k·period for an integer k
                target = coords[ax] + delta
                base = info.values[newpos]
                k = np.round((target - base) / info.period)
                coords[ax] = base + k * info.period
            else:
                coords[ax] = info.values[newpos]

    @staticmethod
    def _splice_stats(parent: SliceStats, dropped: SliceStats,
                      fresh: SliceStats) -> SliceStats | None:
        by_dim = dict(parent.n_slices_by_dim)
        for d, c in dropped.n_slices_by_dim.items():
            by_dim[d] = by_dim.get(d, 0) - c
        for d, c in fresh.n_slices_by_dim.items():
            by_dim[d] = by_dim.get(d, 0) + c
        if any(c < 0 for c in by_dim.values()):
            return None
        return SliceStats(
            n_slices=parent.n_slices - dropped.n_slices + fresh.n_slices,
            n_slices_by_dim={d: c for d, c in by_dim.items() if c},
            n_points=parent.n_points - dropped.n_points + fresh.n_points,
            slicing_time_s=fresh.slicing_time_s + dropped.slicing_time_s)
