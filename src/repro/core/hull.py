"""Convex-hull pruning (paper §3.2 optimisation step).

After each slice, interior vertices are discarded so the vertex count
does not grow quadratically with successive slices.  The paper names
QuickHull [Barber et al. 1996]; ``scipy.spatial.ConvexHull`` *is*
qhull's QuickHull, so we use it for D >= 2 and handle the degenerate
cases (1-D, collinear/coplanar point sets) ourselves — degeneracy is the
common case here because slicing a D-polytope that is flat along some
axis produces rank-deficient vertex sets qhull refuses.
"""

from __future__ import annotations

import numpy as np

_MAX_NO_PRUNE = 8  # hull of <= D+2 points rarely worth the qhull call


def convex_hull_prune(points: np.ndarray) -> np.ndarray:
    """Return the subset of ``points`` on their convex hull.

    Never raises on degenerate input: falls back to an exact
    rank-reduction (project onto the affine span, recurse) and, at worst,
    returns the input unchanged — pruning is an optimisation, not a
    correctness requirement.
    """
    pts = np.asarray(points, np.float64)
    n, d = pts.shape
    if n <= 2 or d == 0:
        return pts
    if d == 1:
        return np.array([[pts[:, 0].min()], [pts[:, 0].max()]])
    if n <= d + 1:
        return pts

    # Rank of the affine span decides whether qhull can run directly.
    centered = pts - pts.mean(0)
    # SVD is cheap: slicing keeps vertex counts small (hull-pruned).
    u, s, vt = np.linalg.svd(centered, full_matrices=False)
    scale = s[0] if s[0] > 0 else 1.0
    rank = int((s > 1e-12 * scale).sum())
    if rank == 0:
        return pts[:1]
    if rank < d:
        # Project to the span, prune there, lift back by selecting rows.
        proj = centered @ vt[:rank].T
        keep = _hull_indices(proj)
        return pts[keep]
    keep = _hull_indices(pts)
    return pts[keep]


def _hull_indices(pts: np.ndarray) -> np.ndarray:
    n, d = pts.shape
    if d == 1:
        return np.unique([int(pts[:, 0].argmin()), int(pts[:, 0].argmax())])
    if n <= d + 1:
        return np.arange(n)
    if d == 2:
        # 2-D is the hot case (the last slicing stage before the 1-D
        # leaves): Andrew's monotone chain in pure numpy beats the
        # scipy/qhull call overhead ~5× at these tiny sizes.
        return _monotone_chain(pts)
    try:
        from scipy.spatial import ConvexHull

        return np.unique(ConvexHull(pts).vertices)
    except Exception:
        # qhull can still fail on near-degenerate input; joggle once.
        try:
            from scipy.spatial import ConvexHull

            return np.unique(ConvexHull(pts, qhull_options="QJ").vertices)
        except Exception:
            return np.arange(n)


def _monotone_chain(pts: np.ndarray) -> np.ndarray:
    """Andrew's monotone chain 2-D convex hull → vertex indices."""
    order = np.lexsort((pts[:, 1], pts[:, 0]))

    def half(idx_iter):
        out: list[int] = []
        for i in idx_iter:
            while len(out) >= 2:
                o, a = pts[out[-2]], pts[out[-1]]
                cross = ((a[0] - o[0]) * (pts[i][1] - o[1])
                         - (a[1] - o[1]) * (pts[i][0] - o[0]))
                # strict `<= 0`: an absolute epsilon here misclassifies
                # subnormal-coordinate hulls (hypothesis-found bug) —
                # keeping a nearly-collinear vertex is harmless, losing
                # a true hull vertex loses extracted points.
                if cross <= 0.0:
                    out.pop()
                else:
                    break
            out.append(int(i))
        return out[:-1]

    lower = half(order)
    upper = half(order[::-1])
    hull = lower + upper
    if not hull:          # all collinear
        return np.unique([int(order[0]), int(order[-1])])
    return np.unique(hull)
