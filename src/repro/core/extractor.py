"""Extraction executors + the baselines the paper compares against.

* :class:`PolytopeExtractor` — the paper's technique: plan with the
  slicer, then read only the planned bytes.  On device the read is a
  sharded gather (``jnp.take``) or the Pallas scalar-prefetch DMA kernel
  (``repro.kernels.gather``) over coalesced runs.
* :class:`BoundingBoxExtractor` — the "state of practice" baseline: the
  tensor-product box of the per-axis extents.
* :class:`TraditionalExtractor` — whole-field reads (paper Table 1
  column 1): everything under the selected leading-axis indices.

All three report bytes-read, so Table 1's reduction factors are computed
like-for-like.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from .datacube import Datacube, OctahedralGridDatacube, TensorDatacube
from .index_tree import ExtractionPlan, coalesce_runs
from .shapes import Request
from .slicer import Slicer, SliceStats


@dataclass
class ExtractResult:
    values: np.ndarray | None
    plan: ExtractionPlan
    stats: SliceStats | None = None

    @property
    def nbytes(self) -> int:
        return self.plan.nbytes


class PolytopeExtractor:
    """Plan on host (float64 geometry) or on device (the fused
    ``device_planner`` pipeline), gather on host or device."""

    def __init__(self, datacube: Datacube, use_kernel: bool = False,
                 verify: bool = False, device_planner: bool = False,
                 burst_gather: bool = False):
        self.datacube = datacube
        self.slicer = Slicer(datacube, verify=verify,
                             device_planner=device_planner)
        self.use_kernel = use_kernel
        # burst_gather=True reads coalesced plan runs as wide contiguous
        # DMA copies (kernels.gather.gather_plan_runs) instead of
        # per-element loads — the bandwidth-bound warm path.
        self.burst_gather = burst_gather

    def plan(self, request: Request) -> tuple[ExtractionPlan, SliceStats]:
        return self.slicer.extract_plan(request)

    def extract(self, request: Request,
                flat_data: Any | None = None) -> ExtractResult:
        plan, stats = self.plan(request)
        values = None
        if flat_data is not None:
            values = gather(flat_data, plan, use_kernel=self.use_kernel,
                            burst=self.burst_gather)
        return ExtractResult(values=values, plan=plan, stats=stats)


def gather(flat_data: Any, plan: ExtractionPlan,
           use_kernel: bool = False, burst: bool = False) -> Any:
    """Read exactly the planned elements.

    ``burst=True`` issues one wide copy per coalesced run
    (run-length-aware DMA) instead of one load per element; results are
    identical — runs tile the offsets exactly.
    """
    if isinstance(flat_data, np.ndarray):
        return flat_data[plan.offsets]
    import jax.numpy as jnp

    if burst:
        from repro.kernels.gather import ops as gops

        return gops.gather_plan_runs(flat_data, plan.run_starts,
                                     plan.run_lengths,
                                     use_pallas=use_kernel)
    offs = jnp.asarray(plan.offsets)
    if use_kernel:
        from repro.kernels.gather import ops as gops

        return gops.gather_rows(flat_data[:, None], offs)[:, 0]
    return jnp.take(flat_data, offs, axis=0)


class BoundingBoxExtractor:
    """Tensor-product box of the request's per-axis extents."""

    def __init__(self, datacube: Datacube):
        self.datacube = datacube

    def plan(self, request: Request) -> ExtractionPlan:
        polys = request.polytopes()
        sels = request.selects()
        # per-axis extents across all polytopes (the box around the union)
        ext: dict[str, list[float]] = {}
        for p in polys:
            for ax in p.axes:
                lo, hi = p.extents(ax)
                cur = ext.setdefault(ax, [lo, hi])
                cur[0] = min(cur[0], lo)
                cur[1] = max(cur[1], hi)

        # Walk the cube like the slicer would, but with box shapes only.
        from .shapes import Box, Select, Span

        shapes: list = [Span(ax, lo, hi) for ax, (lo, hi) in ext.items()]
        shapes += [Select(s.axis, s.values) for s in sels]
        box_request = Request(shapes)
        plan, _ = Slicer(self.datacube).extract_plan(box_request)
        return plan

    def extract(self, request: Request,
                flat_data: Any | None = None) -> ExtractResult:
        plan = self.plan(request)
        values = None
        if flat_data is not None:
            values = gather(flat_data, plan)
        return ExtractResult(values=values, plan=plan)


class TraditionalExtractor:
    """Whole-field baseline: read the complete subcube under the selected
    leading axes (what ECMWF MARS / DICOM effectively do today)."""

    def __init__(self, datacube: Datacube,
                 field_axes: tuple[str, ...] = ("lat", "lon")):
        self.datacube = datacube
        self.field_axes = field_axes

    def nbytes(self, request: Request) -> int:
        """Bytes = (#selected leading-index combinations) × field size."""
        dc = self.datacube
        polys = request.polytopes()
        sels = {s.axis: s for s in request.selects()}
        n_lead = 1
        if isinstance(dc, OctahedralGridDatacube):
            lead_names = dc._lead_names
            field_elems = dc.points_per_field
        elif hasattr(dc, "axis_names"):
            # regular or transformed cube: fields are the trailing
            # (logical) field axes, everything else is a lead axis
            lead_names = tuple(n for n in dc.axis_names
                               if n not in self.field_axes)
            field_elems = int(np.prod([len(dc.axis(n, {})) for n in
                                       self.field_axes]))
        else:
            return dc.nbytes
        for name in lead_names:
            ax = dc.axis(name, {})
            if name in sels:
                n_lead *= len(sels[name].values)
                continue
            on_axis = [p for p in polys if name in p.axes]
            if not on_axis:
                n_lead *= len(ax)
                continue
            lo = min(p.extents(name)[0] for p in on_axis)
            hi = max(p.extents(name)[1] for p in on_axis)
            pos, _ = ax.indices_in_range(lo, hi)
            n_lead *= max(1, len(pos))
        return n_lead * field_elems * dc.dtype.itemsize
