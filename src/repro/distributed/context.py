"""Mesh-axis context: lets model code place sharding constraints by
axis *name* without importing mesh objects.

Launchers (dryrun / train / serve) declare the active axis names once;
``constrain`` then applies ``with_sharding_constraint`` only for axes
that actually exist — the same model code runs unconstrained on a bare
CPU, TP-only on a single pod, or DP×TP×pod on the full mesh.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar

import jax
from jax.sharding import PartitionSpec as P

_AXES: ContextVar[tuple[str, ...]] = ContextVar("repro_mesh_axes",
                                                default=())


def set_mesh_axes(axes: tuple[str, ...]) -> None:
    _AXES.set(tuple(axes))


def mesh_axes() -> tuple[str, ...]:
    return _AXES.get()


@contextlib.contextmanager
def mesh_context(mesh):
    token = _AXES.set(tuple(mesh.axis_names))
    try:
        with mesh:
            yield mesh
    finally:
        _AXES.reset(token)


def _filter(entry, axes):
    if entry is None:
        return None
    names = entry if isinstance(entry, tuple) else (entry,)
    kept = tuple(n for n in names if n in axes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def constrain(x: jax.Array, *spec_dims) -> jax.Array:
    """with_sharding_constraint(x, P(*spec_dims)), dropping axis names
    not present on the active mesh.  No-op without a mesh."""
    axes = mesh_axes()
    if not axes:
        return x
    dims = tuple(_filter(d, axes) for d in spec_dims)
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(x, P(*dims))


DP = ("pod", "data")   # canonical batch-parallel axes
