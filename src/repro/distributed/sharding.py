"""Sharding rules: param-path → PartitionSpec, per model family — plus
the consistent-hash ring that routes plan-cache keys to shards.

Rules are name-based (like MaxText's logical-axis rules): a single
function inspects the pytree path and leaf shape and returns the spec.
All rules speak axis *names* ("data", "model", and optionally "pod"),
so the same model code lowers on any mesh — single-pod (16, 16),
multi-pod (2, 16, 16), or the tiny CI meshes in tests.

Conventions:
 * TP: attention heads / FFN hidden / vocab / MoE experts → "model".
 * Batch-like inputs → ("pod", "data") for training (pod = outer DP).
 * Optimizer state (m/v): the param spec with "data" added on the first
   open dim — ZeRO-1 style state sharding.
 * Stacked-layer params (leading scan dim) get None prepended.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Any, Callable, Iterable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Consistent-hash routing (DESIGN.md §7)
#
# Plan-cache keys are stable sha256 content hashes
# (``Request.canonical_hash``), so the routing point is simply the key's
# leading 64-bit hex prefix — already uniform, never rehashed.  Shards
# get ``replicas`` virtual points on the ring, which keeps balance
# within a few percent and makes shard add/remove move only ~1/N of the
# key space (the classic consistent-hashing guarantee the rebalance
# tests pin down).
# ---------------------------------------------------------------------------

PREFIX_HEX = 16        # leading hex chars of a key → 64-bit ring point
RING_SPACE = 2 ** (4 * PREFIX_HEX)


def key_point(key: str) -> int:
    """Ring position of a canonical-hash key: its 64-bit hex prefix."""
    return int(key[:PREFIX_HEX], 16)


class HashRing:
    """Consistent-hash ring over named shards.

    Lock-free readers: the ring state is one tuple
    ``(nodes, points, owners)`` that mutators rebuild and swap with a
    single attribute store, so a concurrent ``route`` sees either the
    old or the new ring, never a half-built one.  Mutations themselves
    are admin-plane — callers (``ShardedPlanCache.add_shard``) serialize
    them externally.
    """

    def __init__(self, nodes: Iterable[str] = (), replicas: int = 64):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.replicas = replicas
        self._state: tuple[tuple[str, ...], tuple[int, ...],
                           tuple[str, ...]] = ((), (), ())
        for node in nodes:
            self.add_node(node)

    @property
    def nodes(self) -> tuple[str, ...]:
        """Shard names in insertion order."""
        return self._state[0]

    def __len__(self) -> int:
        return len(self._state[0])

    def __contains__(self, node: str) -> bool:
        return node in self._state[0]

    @staticmethod
    def _virtual_points(node: str, replicas: int) -> list[int]:
        return [int(hashlib.sha256(f"{node}#{i}".encode()).hexdigest()
                    [:PREFIX_HEX], 16) for i in range(replicas)]

    def _rebuild(self, nodes: tuple[str, ...]) -> None:
        ring = sorted((p, n) for n in nodes
                      for p in self._virtual_points(n, self.replicas))
        self._state = (nodes, tuple(p for p, _ in ring),
                       tuple(n for _, n in ring))

    def add_node(self, node: str) -> None:
        nodes = self._state[0]
        if node in nodes:
            raise ValueError(f"shard {node!r} already on the ring")
        self._rebuild(nodes + (node,))

    def remove_node(self, node: str) -> None:
        nodes = self._state[0]
        if node not in nodes:
            raise KeyError(node)
        self._rebuild(tuple(n for n in nodes if n != node))

    def route(self, key: str) -> str:
        """Owning shard of a canonical-hash key (clockwise successor of
        the key's 64-bit prefix point on the ring)."""
        _, points, owners = self._state
        if not owners:
            raise RuntimeError("HashRing has no nodes")
        i = bisect.bisect_right(points, key_point(key))
        return owners[i % len(owners)]


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        else:
            names.append(str(p))
    return names


def lm_rules(path, shape: tuple[int, ...]) -> P:
    """Transformer sharding (GQA / MLA / MoE / dense)."""
    names = _path_names(path)
    leaf = names[-1]
    stacked = "groups" in names         # scan-stacked → leading L dim
    inner = shape[1:] if stacked else shape

    def spec(*dims):
        full = (None,) + dims if stacked else dims
        return P(*full[: len(shape)])

    if leaf in ("scale", "bias", "b"):
        return spec(None)
    if "router" in names:
        return spec(None, None)
    if leaf in ("w_gate", "w_up") and len(inner) == 3:     # MoE (E, D, F)
        return spec("model", None, None)
    if leaf == "w_down" and len(inner) == 3:               # MoE (E, F, D)
        return spec("model", None, None)
    if "embed" in names or leaf == "table":                # (V, D)
        return spec("model", None)
    if leaf in ("wq", "wk", "wv", "wq_b", "wk_b", "wv_b"):
        return spec(None, "model")                         # (…, H·Dh)
    if leaf in ("wq_a", "wkv_a"):
        return spec(None, "model")                         # low-rank in
    if leaf == "wo":
        return spec("model", None)                         # (H·Dh, D)
    if leaf in ("w_gate", "w_up"):                         # dense (D, F)
        return spec(None, "model")
    if leaf == "w_down":                                   # dense (F, D)
        return spec("model", None)
    if leaf == "w":                                        # generic dense
        if len(inner) == 2:
            return spec(None, "model")
        return spec(*([None] * len(inner)))
    return P(*([None] * len(shape)))


def gnn_rules(path, shape: tuple[int, ...]) -> P:
    """NequIP params are tiny — replicate everything."""
    return P(*([None] * len(shape)))


def recsys_rules(path, shape: tuple[int, ...]) -> P:
    names = _path_names(path)
    leaf = names[-1]
    if leaf == "tables" and len(shape) == 3:     # (T, rows, D) row-shard
        return P(None, "model", None)
    if leaf == "table" and len(shape) == 2:      # (rows, D) row-shard
        return P("model", None)
    if ("tower" in " ".join(names) or "deep" in names or "top" in names
            or "bot" in names) and leaf == "w" and len(shape) == 2:
        return P(None, None)                     # small MLPs replicated
    # bert4rec reuses the transformer
    return lm_rules(path, shape)


RULES: dict[str, Callable] = {
    "lm": lm_rules,
    "gnn": gnn_rules,
    "recsys": recsys_rules,
}


def param_specs(params: Any, rules: Callable) -> Any:
    """PartitionSpec tree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rules(path, np.shape(leaf)), params)


DATA_AXIS_SIZE = 16   # production data-axis extent (per pod)
POD_AXIS_SIZE = 2     # pods on the multi-pod mesh

# FSDP shards over data *and* pod: 671B-class models only fit when the
# cross-pod axis also carries parameter shards (sanitize_specs degrades
# this to data-only on single-pod meshes).
FSDP_AXES = ("data", "pod")


def add_data_axis(spec: P, shape: tuple[int, ...],
                  min_size: int = 2 ** 16,
                  data_size: int = DATA_AXIS_SIZE * POD_AXIS_SIZE,
                  axes: tuple = FSDP_AXES) -> P:
    """Add the FSDP axes on the first open, evenly-divisible dim of a
    ≥2-D tensor (ZeRO/FSDP).  jit input shardings require exact
    divisibility, so dims not divisible by the full extent are skipped."""
    if len(shape) < 2 or int(np.prod(shape)) < min_size:
        return spec
    flat = [a for d in spec if d is not None
            for a in (d if isinstance(d, tuple) else (d,))]
    if any(a in flat for a in axes):
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, d in enumerate(dims):
        if d is None and shape[i] > 1 and shape[i] % data_size == 0:
            dims[i] = axes
            break
    return P(*dims)


def sanitize_specs(spec_tree: Any, aval_tree: Any, mesh: Mesh) -> Any:
    """Make spec trees legal for this mesh: drop axis names the mesh
    does not have (rules may speak of "pod" on single-pod meshes), and
    drop axes whose product doesn't divide the dim size.

    jit ``in_shardings`` reject uneven partitions, and published configs
    have plenty of awkward extents (49155-token vocabs, 26 tables, 61
    layers) — any non-divisible dim falls back to replication on that
    dim, everything else keeps its sharding."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, aval):
        if not isinstance(spec, P):
            return spec
        shape = tuple(getattr(aval, "shape", ()))
        dims = list(spec)[: len(shape)]
        out = []
        for i, d in enumerate(dims):
            if d is None:
                out.append(None)
                continue
            axes = tuple(a for a in
                         (d if isinstance(d, tuple) else (d,))
                         if a in sizes)
            if not axes:
                out.append(None)
                continue
            total = int(np.prod([sizes[a] for a in axes]))
            if shape[i] % total:
                out.append(None)
            else:
                out.append(axes if len(axes) > 1 else axes[0])
        return P(*out)

    return jax.tree.map(fix, spec_tree, aval_tree,
                        is_leaf=lambda x: isinstance(x, P) or x is None)


def opt_state_specs(pspec_tree: Any, params: Any,
                    min_size: int = 2 ** 16) -> Any:
    """ZeRO-1: add "data" on the first open dim of each ≥2-D param."""
    return jax.tree.map(
        lambda spec, leaf: add_data_axis(spec, np.shape(leaf), min_size),
        pspec_tree, params)


def fsdp_rules(base_rules: Callable) -> Callable:
    """Wrap family rules with FSDP: params additionally shard on "data".

    Embedding tables are exempt: a token gather over a table sharded on
    *both* vocab and feature dims hits SPMD's involuntary-full-remat
    path (vocab-only sharding lowers to the standard masked-gather +
    all-reduce)."""
    def rules(path, shape):
        names = _path_names(path)
        if "embed" in names or names[-1] == "table":
            return base_rules(path, shape)
        return add_data_axis(base_rules(path, shape), shape)

    return rules


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> tuple:
    """The combined data-parallel axes present on this mesh."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else (mesh.axis_names[0],)
