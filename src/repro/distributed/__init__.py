from . import compression, context, sharding  # noqa: F401
