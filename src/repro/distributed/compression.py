"""Gradient compression: int8 quantised all-reduce with error feedback.

Cross-pod (DCI) bandwidth is the scarcest link in a multi-pod mesh; the
standard trick is to compress the gradient all-reduce and carry the
quantisation error into the next step (error feedback keeps SGD/Adam
convergence — Karimireddy et al. '19).

``compress_grads`` is a drop-in ``compressor`` for
``repro.train.train_state.make_train_step``: state gains an
``"ef"`` (error-feedback) buffer tree.  Quantisation is per-tensor
symmetric int8; the all-reduce itself stays in XLA's hands (psum of the
dequantised tensor lowers to an int-width-reduced transfer when the
compiler can prove it — on real DCI deployments the quantised payload is
all-reduced via shard_map, see ``quantized_psum``).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Params, state: dict) -> tuple[Params, dict]:
    """Error-feedback int8 compression of the gradient tree.

    Used as ``make_train_step(..., compressor=compress_grads)`` with
    ``state["ef"]`` initialised via :func:`init_error_feedback`.
    """
    ef = state.get("ef")
    if ef is None:
        ef = init_error_feedback(grads)

    def comp(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(comp, grads, ef)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, {**state, "ef": new_ef}


def quantized_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-payload all-reduce inside shard_map: quantise → psum int32 →
    dequantise.  Payload over the wire is 1 byte/elem + one f32 scale
    (vs 4 bytes/elem) — the cross-pod gradient reduction pattern."""
    q, scale = quantize_int8(x)
    # max-scale so all peers dequantise compatibly
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
