"""Benchmark harness — one target per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the §Roofline table
when dry-run results exist).

  python -m benchmarks.run                 # everything (small grids)
  python -m benchmarks.run --full          # Table 1 at O1280 + roofline
  python -m benchmarks.run --only table1
"""

from __future__ import annotations

import argparse
import sys


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def run_fig8() -> None:
    from . import fig8_performance as f8

    rows = f8.fig8a_b()
    for r in rows:
        _emit(f"fig8ab_dim{r['ndim']}_n{r['n_points']}",
              r["slicing_s"] * 1e6,
              f"total_us={r['total_s'] * 1e6:.1f};slices={r['n_slices']}")
    lin = f8.linearity_check(rows)
    for d, us in sorted(lin["us_per_point_by_dim"].items()):
        _emit(f"fig8b_slope_dim{d}", us, "us_per_extracted_point")
    for r in f8.fig8c():
        _emit(f"fig8c_union{r['n_subshapes']}", r["slicing_s"] * 1e6,
              f"n_points={r['n_points']};slices={r['n_slices']}")
    for r in f8.fig8d():
        _emit(f"fig8d_{r['shape']}_r{r['radius']}",
              r["slicing_s"] * 1e6, f"n_points={r['n_points']}")


def run_table1(full: bool) -> None:
    from . import table1_reductions as t1

    rows = t1.table1(n=1280 if full else 128,
                     mri_size=512 if full else 128)
    for r in rows:
        _emit(f"table1_{r['example']}", r["slicing_s"] * 1e6,
              f"poly_B={r['polytope_bytes']};bbox_B={r['bbox_bytes']};"
              f"trad_B={r['traditional_bytes']};"
              f"red_trad={r['reduction_vs_traditional']:.0f}x;"
              f"red_bbox={r['reduction_vs_bbox']:.2f}x")


def run_kernels() -> None:
    from . import bench_kernels as bk

    for r in bk.bench():
        _emit(r["name"], r["us_per_call"], r["derived"])


def run_plancache() -> None:
    from . import bench_plan_cache as bpc

    for r in bpc.bench():
        _emit(r["name"], r["us_per_call"], r["derived"])


def run_roofline(full: bool = False) -> None:
    import os

    from . import roofline

    # The measured kernels roofline always runs (no dry-run artifacts
    # needed): device planning vs the cold host loop + burst gather
    # bandwidth, persisted to BENCH_kernels.json.
    sizes = {} if full else dict(n_lat=96, n_lon=192, n_grid=128)
    rows = roofline.kernels_table(repeats=3, **sizes)
    for r in rows:
        _emit(f"kernels_{r['scenario']}", r["device_plan_us"],
              f"host_us={r['host_plan_us']:.0f};"
              f"speedup={r['plan_speedup']:.2f}x;"
              f"burst_us={r['burst_gather_us']:.0f};"
              f"gbps={r['gather_gbps']:.2f};"
              f"compress={r['compress_ratio']:.2f}")
    roofline.write_kernels_bench(rows)

    if not os.path.exists("results/dryrun.json"):
        print("roofline,dryrun-table-skipped,no results/dryrun.json",
              file=sys.stderr)
        return
    for r in roofline.roofline_table():
        _emit(f"roofline_{r['arch']}_{r['shape']}",
              max(r["t_compute_s"], r["t_memory_s"],
                  r["t_collective_s"]) * 1e6,
              f"bottleneck={r['bottleneck']};"
              f"t_comp={r['t_compute_s']:.4f};t_mem={r['t_memory_s']:.4f};"
              f"t_coll={r['t_collective_s']:.4f};"
              f"useful={r.get('useful_ratio', float('nan')):.3f}")


TARGETS = {
    "fig8": run_fig8,
    "table1": lambda full=False: run_table1(full),
    "kernels": run_kernels,
    "plancache": run_plancache,
    "roofline": lambda full=False: run_roofline(full),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(TARGETS))
    ap.add_argument("--full", action="store_true",
                    help="Table 1 at the paper's O1280 resolution")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.only:
        if args.only in ("table1", "roofline"):
            TARGETS[args.only](args.full)
        else:
            TARGETS[args.only]()
        return
    run_fig8()
    # default to the paper's O1280 resolution — the headline numbers
    run_table1(True)
    run_kernels()
    run_plancache()
    run_roofline(True)


if __name__ == "__main__":
    main()
