"""Roofline analysis from the dry-run's compiled artifacts.

Three terms per (arch × shape), single-pod mesh (deliverable g):

  compute    = FLOPs/device   / peak_FLOP/s        (197 TF bf16, v5e)
  memory     = bytes/device   / HBM_bw             (819 GB/s)
  collective = coll_bytes/dev / ICI link bw        (~50 GB/s/link)

Scan correction: XLA's cost_analysis counts while-loop bodies once, so
scanned-layer models are corrected with the unrolled micro-probes
(dryrun keys ``…|probe:pXY``):

  micro(L) = p11 + Σ_g (n_g − 1) · (probe_g(2) − p11)
  total    = accum × micro(L) + analytic optimizer cost   (train)
           = micro(L)                                      (serve)

MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) gives the useful-compute
ratio; the dominant term names the bottleneck each §Perf iteration
attacks.

The second half of this module is the **kernels roofline**: measured
slice/plan/gather timings for the device-resident planning pipeline
(``repro.core.DevicePlanner`` + ``repro.kernels.gather`` burst DMA)
against the cold host planner, written to ``BENCH_kernels.json`` so the
kernel-perf trajectory is tracked PR-over-PR.  Unlike the dry-run
roofline above it needs no ``results/dryrun.json`` — it times live code.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

# analytic per-param optimizer costs (flops, bytes) per step
OPT_COST = {"adamw": (12, 28), "adafactor": (8, 16)}

# active params (for 6·N_active·D); computed from configs at report time
_N_ACTIVE_CACHE: dict[str, float] = {}


def n_params_active(arch_id: str) -> tuple[float, float]:
    """(total params, active params per token)."""
    if arch_id in _N_ACTIVE_CACHE:
        return _N_ACTIVE_CACHE[arch_id]
    import jax
    import numpy as np

    from repro.configs import get_arch

    arch = get_arch(arch_id)
    total = None
    if arch.correction is not None:
        total = arch.correction().get("n_params")
    if total is None:
        smoke = arch.smoke()
        total = sum(int(np.prod(l.shape)) for l in
                    jax.tree.leaves(smoke["state"]["params"]))
    active = total
    if arch.family == "lm":
        from repro.configs import _MODULES
        import importlib

        cfg = importlib.import_module(
            f"repro.configs.{_MODULES[arch_id]}")._cfg()
        if cfg.moe is not None:
            e, k = cfg.moe.n_experts, cfg.moe.top_k
            # expert params scale by k/E; shared+dense+attn stay active
            expert_layers = cfg.n_layers - cfg.n_dense_layers
            per_expert = 3 * cfg.d_model * cfg.moe.d_ff
            expert_total = expert_layers * e * per_expert
            active = total - expert_total + expert_layers * k * per_expert
    _N_ACTIVE_CACHE[arch_id] = (float(total), float(active))
    return _N_ACTIVE_CACHE[arch_id]


def tokens_for(shape: str, kind: str) -> float:
    from repro.configs.common import LM_SHAPES

    if shape in LM_SHAPES:
        info = LM_SHAPES[shape]
        if kind == "decode":
            return info["batch"]          # one token per sequence
        return info["batch"] * info["seq"]
    return 0.0


def corrected_costs(results: dict, key: str) -> dict:
    """Apply the probe-based scan correction to one cell."""
    rec = results[key]
    base = {
        "flops": rec["cost"]["flops_per_device"],
        "bytes": rec["cost"]["bytes_accessed_per_device"],
        "coll": rec["collectives"]["total_bytes"],
        "corrected": False,
    }
    corr = rec.get("correction")
    arch, shape = rec["arch"], rec["shape"]
    p11 = results.get(f"{arch}|{shape}|sp|probe:p11")
    p21 = results.get(f"{arch}|{shape}|sp|probe:p21")
    if not corr or not p11 or not p11.get("ok") or not p21 or not \
            p21.get("ok"):
        return base

    def probe_vals(p):
        return (p["cost"]["flops_per_device"],
                p["cost"]["bytes_accessed_per_device"],
                p["collectives"]["total_bytes"])

    f11, b11, c11 = probe_vals(p11)
    groups = corr["groups"]
    if corr.get("two_groups"):
        p12 = results.get(f"{arch}|{shape}|sp|probe:p12")
        if not p12 or not p12.get("ok"):
            return base
        f21, b21, c21 = probe_vals(p21)
        f12, b12, c12 = probe_vals(p12)
        nd, nm = groups
        f = f11 + (nd - 1) * (f21 - f11) + (nm - 1) * (f12 - f11)
        b = b11 + (nd - 1) * (b21 - b11) + (nm - 1) * (b12 - b11)
        c = c11 + (nd - 1) * (c21 - c11) + (nm - 1) * (c12 - c11)
    else:
        f21, b21, c21 = probe_vals(p21)
        (n1,) = groups
        f = f11 + (n1 - 1) * (f21 - f11)
        b = b11 + (n1 - 1) * (b21 - b11)
        c = c11 + (n1 - 1) * (c21 - c11)

    if rec["kind"] == "train":
        a = corr["accum"]
        of, ob = OPT_COST[corr["opt_kind"]]
        n_dev = rec["n_devices"]
        opt_f = of * corr["n_params"] / n_dev
        opt_b = ob * corr["n_params"] / n_dev
        return {"flops": a * f + opt_f, "bytes": a * b + opt_b,
                "coll": a * c, "corrected": True}
    return {"flops": f, "bytes": b, "coll": c, "corrected": True}


def roofline_table(dryrun_path: str = "results/dryrun.json",
                   mesh: str = "sp") -> list[dict]:
    results = json.loads(Path(dryrun_path).read_text())
    rows = []
    for key, rec in sorted(results.items()):
        if not key.endswith(f"|{mesh}") or not rec.get("ok"):
            continue
        cost = corrected_costs(results, key)
        t_comp = cost["flops"] / PEAK_FLOPS
        t_mem = cost["bytes"] / HBM_BW
        t_coll = cost["coll"] / ICI_BW
        dom = max(("compute", t_comp), ("memory", t_mem),
                  ("collective", t_coll), key=lambda kv: kv[1])[0]
        row = dict(arch=rec["arch"], shape=rec["shape"],
                   kind=rec["kind"],
                   flops_per_dev=cost["flops"],
                   bytes_per_dev=cost["bytes"],
                   coll_bytes_per_dev=cost["coll"],
                   t_compute_s=t_comp, t_memory_s=t_mem,
                   t_collective_s=t_coll, bottleneck=dom,
                   corrected=cost["corrected"],
                   mem_temp_gib=rec["memory"]["temp_bytes"] / 2 ** 30,
                   mem_args_gib=rec["memory"]["argument_bytes"] / 2 ** 30)
        # useful-compute ratio for LM cells
        if rec["arch"] in ("deepseek-v3-671b", "arctic-480b", "glm4-9b",
                           "yi-34b", "granite-3-8b"):
            total, active = n_params_active(rec["arch"])
            toks = tokens_for(rec["shape"], rec["kind"])
            mult = 6.0 if rec["kind"] == "train" else 2.0
            model_flops = mult * active * toks / rec["n_devices"]
            row["model_flops_per_dev"] = model_flops
            row["useful_ratio"] = (model_flops / cost["flops"]
                                   if cost["flops"] else 0.0)
        rows.append(row)
    return rows


def print_table(rows: list[dict]) -> None:
    hdr = (f"{'arch':<22}{'shape':<16}{'bottleneck':<11}"
           f"{'t_comp(s)':>10}{'t_mem(s)':>10}{'t_coll(s)':>10}"
           f"{'useful':>7}{'temp GiB':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        u = f"{r.get('useful_ratio', float('nan')):.2f}" \
            if "useful_ratio" in r else "  -"
        print(f"{r['arch']:<22}{r['shape']:<16}{r['bottleneck']:<11}"
              f"{r['t_compute_s']:>10.4f}{r['t_memory_s']:>10.4f}"
              f"{r['t_collective_s']:>10.4f}{u:>7}"
              f"{r['mem_temp_gib']:>9.1f}")


# ---------------------------------------------------------------------------
# kernels roofline: device planning + burst gather vs the host loop
# ---------------------------------------------------------------------------

def _best_us(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def kernels_scenarios(n_lat: int = 320, n_lon: int = 640,
                      n_grid: int = 512) -> list[tuple]:
    """(name, datacube, request) triples for the device-planning bench.

    Country polygons on the irregular weather cube (merged datetime,
    mapped Gaussian latitudes, cyclic longitude — uk straddles the seam)
    plus a disk on a regular grid: all polygon requests, i.e. the host
    planner's slow per-row slicing path, Table-1 shapes."""
    import numpy as np

    from repro.core import (Disk, OrderedAxis, Request, Select,
                            TensorDatacube)
    from repro.dataplane.weather import IrregularWeatherCube

    iwc = IrregularWeatherCube(n_dates=2, times_per_day=4, n_levels=3,
                               n_lat=n_lat, n_lon=n_lon)
    scens = [(f"irregular_{c}", iwc.cube, iwc.country_request(c))
             for c in ("germany", "france", "uk")]

    cube = TensorDatacube([
        OrderedAxis("t", np.arange(4.0)),
        OrderedAxis("x", np.arange(float(n_grid))),
        OrderedAxis("y", np.arange(float(n_grid))),
    ], dtype=np.float32)
    disk = Request([Select("t", [0.0]),
                    Disk(("x", "y"), (n_grid / 2.0, n_grid / 2.0),
                         n_grid * 0.4, segments=24)])
    scens.append((f"grid_disk_{n_grid}", cube, disk))
    return scens


def kernels_table(n_lat: int = 320, n_lon: int = 640, n_grid: int = 512,
                  repeats: int = 5) -> list[dict]:
    """Measured slice/plan/gather roofline rows.

    * ``host_plan_us``   — cold host planner: full Algorithm-1 BFS per
      call (there is no plan cache at this layer).
    * ``device_plan_us`` — warm fused pipeline (``DevicePlanner.plan``):
      one device invocation + host plan post-processing; the jit compile
      is excluded (warm-up call), the per-request work is not.
    * ``gather_us`` / ``burst_gather_us`` — per-element ``jnp.take``
      vs run-length-aware burst DMA over the same plan.
    * ``gather_gbps`` / ``roofline_frac`` — burst-gather read bandwidth
      and its fraction of the HBM roofline (``HBM_BW``).
    * ``compress_ratio`` — int64 offsets vs the delta-encoded int32
      :class:`repro.core.CompressedPlan` byte size.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DevicePlanner, Slicer, compress_plan
    from repro.kernels.gather import ops as gops

    rows = []
    for name, cube, request in kernels_scenarios(n_lat, n_lon, n_grid):
        host = Slicer(cube)
        dp = DevicePlanner(cube)
        out = dp.plan(request)          # warm-up (jit compile) + guard
        if out is None:
            raise RuntimeError(f"{name}: request fell off the device "
                               "pipeline — bench scenarios must be "
                               "device-plannable")
        plan, _ = out
        host_plan, _ = host.extract_plan(request)
        if not np.array_equal(plan.offsets, host_plan.offsets):
            raise RuntimeError(f"{name}: device plan diverged from host")

        host_us = _best_us(lambda: host.extract_plan(request), repeats)
        dev_us = _best_us(lambda: dp.plan(request), repeats)

        flat = jnp.zeros(cube.n_elements, jnp.float32)
        offs = jnp.asarray(plan.offsets)
        take = lambda: jnp.take(flat, offs, axis=0).block_until_ready()
        burst = lambda: gops.gather_plan_runs(
            flat, plan.run_starts, plan.run_lengths).block_until_ready()
        take()
        burst()                         # warm both gather paths
        gather_us = _best_us(take, repeats)
        burst_us = _best_us(burst, repeats)

        bytes_read = plan.n_points * flat.dtype.itemsize
        gbps = bytes_read / (burst_us * 1e-6) / 1e9
        cp = compress_plan(plan)
        rows.append(dict(
            scenario=name,
            n_points=int(plan.n_points),
            n_runs=int(len(plan.run_starts)),
            host_plan_us=host_us,
            device_plan_us=dev_us,
            plan_speedup=host_us / dev_us,
            gather_us=gather_us,
            burst_gather_us=burst_us,
            gather_gbps=gbps,
            roofline_frac=gbps * 1e9 / HBM_BW,
            compress_ratio=plan.offsets.nbytes / cp.nbytes_encoded,
        ))
    return rows


def write_kernels_bench(rows: list[dict],
                        out_path: str = "BENCH_kernels.json") -> None:
    with open(out_path, "w") as fh:
        json.dump({"bench": "kernels", "rows": rows}, fh, indent=2)


def print_kernels_table(rows: list[dict]) -> None:
    hdr = (f"{'scenario':<22}{'points':>8}{'runs':>6}"
           f"{'host us':>10}{'dev us':>9}{'speedup':>8}"
           f"{'burst us':>9}{'GB/s':>7}{'compress':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['scenario']:<22}{r['n_points']:>8}{r['n_runs']:>6}"
              f"{r['host_plan_us']:>10.0f}{r['device_plan_us']:>9.0f}"
              f"{r['plan_speedup']:>8.2f}{r['burst_gather_us']:>9.0f}"
              f"{r['gather_gbps']:>7.2f}{r['compress_ratio']:>9.2f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--fast", action="store_true",
                    help="small cubes (CI smoke)")
    args = ap.parse_args()

    if Path("results/dryrun.json").exists():
        print_table(roofline_table())
        print()
    sizes = dict(n_lat=96, n_lon=192, n_grid=128) if args.fast else {}
    rows = kernels_table(repeats=args.repeats, **sizes)
    print_kernels_table(rows)
    write_kernels_bench(rows, args.out)
    print(f"wrote {args.out}")
