"""Plan-cache benchmark (DESIGN.md §4): cold- vs warm-plan latency and
served plans/sec under a Zipfian request mix.

Production request streams are repetitive — a few hot crops dominate.
This measures exactly what the extraction service buys: a cache hit is
an O(1) hash + LRU lookup, a cold plan is a full Algorithm-1 run.

  PYTHONPATH=src python -m benchmarks.bench_plan_cache
"""

from __future__ import annotations

import time

import numpy as np


def bench(grid_n: int = 48, n_requests: int = 2000, zipf_s: float = 1.3,
          capacity: int = 256, seed: int = 0) -> list[dict]:
    from repro.dataplane.weather import WeatherCube, request_population
    from repro.serve.extraction import ExtractionService

    wc = WeatherCube(n=grid_n, n_times=4, n_levels=4)
    population = request_population(wc)
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(zipf_s, size=n_requests) - 1,
                       len(population) - 1)

    # -- cold-plan latency: every unique request, empty cache ------------
    svc = ExtractionService(wc.cube, capacity=capacity)
    t0 = time.perf_counter()
    for req in population:
        svc.plan(req)
    cold_s = (time.perf_counter() - t0) / len(population)

    # -- warm-plan latency: the same requests, now all cached ------------
    t0 = time.perf_counter()
    for req in population:
        svc.plan(req)
    warm_s = (time.perf_counter() - t0) / len(population)

    # -- Zipfian serving throughput: cached vs cache-bypassing -----------
    svc = ExtractionService(wc.cube, capacity=capacity)
    t0 = time.perf_counter()
    for r in ranks:
        svc.plan(population[r])
    cached_dt = time.perf_counter() - t0
    hit_rate = svc.stats.hit_rate

    t0 = time.perf_counter()
    for r in ranks:
        svc.extractor.plan(population[r])        # no cache, Alg. 1 always
    uncached_dt = time.perf_counter() - t0

    return [
        {"name": "plancache_cold_plan", "us_per_call": cold_s * 1e6,
         "derived": f"population={len(population)}"},
        {"name": "plancache_warm_plan", "us_per_call": warm_s * 1e6,
         "derived": f"speedup={cold_s / warm_s:.1f}x"},
        {"name": "plancache_zipf_cached",
         "us_per_call": cached_dt / n_requests * 1e6,
         "derived": f"plans_per_s={n_requests / cached_dt:.0f};"
                    f"hit_rate={hit_rate:.2f}"},
        {"name": "plancache_zipf_uncached",
         "us_per_call": uncached_dt / n_requests * 1e6,
         "derived": f"plans_per_s={n_requests / uncached_dt:.0f};"
                    f"speedup={uncached_dt / cached_dt:.1f}x"},
    ]


def main() -> None:
    print("name,us_per_call,derived")
    rows = bench()
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    cold = next(r for r in rows if r["name"] == "plancache_cold_plan")
    warm = next(r for r in rows if r["name"] == "plancache_warm_plan")
    ratio = cold["us_per_call"] / warm["us_per_call"]
    print(f"# warm plan is {ratio:.0f}x faster than cold "
          f"({'PASS' if ratio >= 10 else 'FAIL'}: target >= 10x)")


if __name__ == "__main__":
    main()
