"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python
— correctness only), so the timed numbers compare the *jnp reference
paths* that XLA:CPU executes; Pallas-vs-ref equality is asserted in
tests.  Derived columns report bytes moved per call — the quantity the
TPU kernel's DMA plan controls.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gather import ref as gref
from repro.kernels.paged_attn import ref as pref
from repro.kernels.segment import ref as sref
from repro.kernels.slice import ref as slref


def _time(fn, *args, repeats=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6   # µs


def bench() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # gather_rows: the exact-byte extraction read
    for n, d, m in [(100_000, 64, 4096), (1_000_000, 64, 65_536)]:
        table = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, m).astype(np.int32))
        f = jax.jit(gref.gather_rows)
        us = _time(f, table, idx)
        rows.append(dict(name=f"gather_rows_{n}x{d}_m{m}",
                         us_per_call=us,
                         derived=f"{m * d * 4 / 1e6:.1f}MB_read"))

    # embedding bag
    table = jnp.asarray(rng.normal(size=(100_000, 64)), jnp.float32)
    bags = jnp.asarray(rng.integers(-1, 100_000, (8192, 4)).astype(
        np.int32))
    us = _time(jax.jit(gref.gather_rows_bag), table, bags)
    rows.append(dict(name="gather_bag_8192x4", us_per_call=us,
                     derived=f"{8192 * 4 * 64 * 4 / 1e6:.1f}MB_read"))

    # batched polytope slicing (one BFS layer)
    verts = jnp.asarray(rng.uniform(0, 10, (1024, 8, 4)), jnp.float32)
    valid = jnp.ones((1024, 8), bool)
    planes = jnp.asarray(rng.uniform(0, 10, 1024), jnp.float32)
    f = jax.jit(lambda v, m, p: slref.slice_batch(v, m, p, 1))
    us = _time(f, verts, valid, planes)
    rows.append(dict(name="slice_batch_1024x8x4", us_per_call=us,
                     derived=f"{1024 / max(us, 1e-9):.1f}polytopes_per_us"))

    # paged decode attention
    B, H, KVH, DH, PS, NP, PM = 16, 16, 4, 64, 16, 512, 32
    q = jnp.asarray(rng.normal(size=(B, H, DH)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NP, KVH, PS, DH)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NP, KVH, PS, DH)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, NP, (B, PM)).astype(np.int32))
    lens = jnp.asarray(rng.integers(1, PS * PM, B).astype(np.int32))
    us = _time(jax.jit(pref.paged_decode_attention), q, kp, vp, bt, lens)
    live = float(jnp.sum(jnp.ceil(lens / PS))) * PS * KVH * DH * 4 * 2
    rows.append(dict(name="paged_attn_b16_s512", us_per_call=us,
                     derived=f"{live / 1e6:.1f}MB_live_pages"))

    # segment sum (GNN aggregation)
    msg = jnp.asarray(rng.normal(size=(100_000, 64)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, 4096, 100_000).astype(np.int32))
    f = jax.jit(lambda m, s: sref.segment_sum(m, s, 4096))
    us = _time(f, msg, seg)
    rows.append(dict(name="segment_sum_100k_to_4k", us_per_call=us,
                     derived=f"{100_000 * 64 * 4 / 1e6:.1f}MB_scattered"))
    return rows
