"""Delta-planning benchmark (DESIGN.md §8): drifting Zipfian request
streams against the neighborhood index and plan splicer.

Operational request streams are not just repetitive — they *drift*: the
same crop shape tracks a storm front east or a rolling time window
advances one forecast step per arrival.  Exact-key caching whiffs on
every arrival of such a stream; the delta planner recognises the
translated signature and splices the parent plan instead of re-running
Algorithm 1.

Each scenario replays an identical stream twice:

  cold  — ``ExtractionService(delta=False)``: every drifted arrival is
          an exact-cache miss and a full Algorithm-1 plan.
  warm  — ``ExtractionService(delta=True)``: drifted arrivals splice
          from the neighborhood index; only stream-openers plan cold.

Drift offsets are exact float64 multiples of the axis step (21600 s
datetime, 1.875 deg lon) so spliced plans are byte-identical to cold
plans — pass ``--verify`` to run ``verify_plan`` on every spliced plan
while timing.

  PYTHONPATH=src python benchmarks/bench_delta.py [--fast] [--verify]

Writes ``BENCH_delta.json`` (rows schema-checked by
``python -m repro.analysis --bench BENCH_delta.json``).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

LON_STEP = 1.875            # 360 / 192, exact in float64 (15/8)
DT_STEP = 21600.0           # 6-hourly forecast step
ZIPF_S = 1.3


def _zipf_ranks(rng: np.random.Generator, n: int, n_bases: int) -> np.ndarray:
    return np.minimum(rng.zipf(ZIPF_S, size=n) - 1, n_bases - 1)


def _seam_stream(cube, rng, n_requests: int, drift_steps: int) -> list:
    """Wide boxes tracking east across the lon seam."""
    bases = [(15.0, 55.0, -30.0, 30.0), (-20.0, 20.0, 140.0, 200.0),
             (30.0, 70.0, 40.0, 110.0), (-45.0, -5.0, -90.0, -30.0)]
    offsets = [0] * len(bases)
    stream = []
    for rank in _zipf_ranks(rng, n_requests, len(bases)):
        offsets[rank] += int(rng.integers(1, drift_steps + 1))
        lat_lo, lat_hi, lon_lo, lon_hi = bases[rank]
        d = (offsets[rank] % 192) * LON_STEP
        stream.append(cube.seam_box_request(lat_lo, lat_hi,
                                            lon_lo + d, lon_hi + d))
    return stream


def _storm_stream(cube, rng, n_requests: int, drift_steps: int) -> list:
    """Country-shaped crops translating east (storm tracking)."""
    from repro.core import Polygon, Request, Select
    from repro.dataplane.weather import COUNTRIES

    names = sorted(COUNTRIES)
    offsets = [0] * len(names)
    stream = []
    for rank in _zipf_ranks(rng, n_requests, len(names)):
        offsets[rank] += int(rng.integers(1, drift_steps + 1))
        d = (offsets[rank] % 192) * LON_STEP
        verts = COUNTRIES[names[rank]].copy()
        verts[:, 1] += d
        stream.append(Request([Select("datetime", [0.0]),
                               Select("level", [0.0]),
                               Polygon(("lat", "lon"), verts)]))
    return stream


def _window_stream(cube, rng, n_requests: int, drift_steps: int) -> list:
    """Rolling forecast windows advancing along the leading axis."""
    from repro.core import Box, Request, Span

    n_steps = cube.n_dates * cube.times_per_day
    window = n_steps // 2
    max_t0 = n_steps - window - 1
    bases = [(10.0, 50.0, -20.0, 25.0), (-30.0, 10.0, 100.0, 150.0)]
    offsets = [0] * len(bases)
    stream = []
    for rank in _zipf_ranks(rng, n_requests, len(bases)):
        offsets[rank] += int(rng.integers(1, drift_steps + 1))
        t0 = (offsets[rank] % (max_t0 + 1)) * DT_STEP
        lat_lo, lat_hi, lon_lo, lon_hi = bases[rank]
        stream.append(Request([
            Span("datetime", t0, t0 + (window - 1) * DT_STEP),
            Box(("lat", "lon"), [lat_lo, lon_lo], [lat_hi, lon_hi])]))
    return stream


def _run_stream(datacube, stream, *, delta: bool, verify: bool) -> tuple:
    from repro.serve.extraction import ExtractionService

    svc = ExtractionService(datacube, capacity=4096, verify=verify,
                            delta=delta)
    t0 = time.perf_counter()
    for req in stream:
        svc.plan(req)
    wall = time.perf_counter() - t0
    return wall, svc.stats


def bench(n_requests: int = 400, drift_steps: int = 3, seed: int = 0,
          verify: bool = False) -> list[dict]:
    from repro.dataplane.weather import IrregularWeatherCube

    wcube = IrregularWeatherCube(n_dates=8, times_per_day=4)
    rows = []
    scenarios = [
        ("seam_lon_drift", _seam_stream, drift_steps),
        ("storm_track_lon_drift", _storm_stream, drift_steps),
        ("rolling_window_drift", _window_stream, 1),
    ]
    for name, make, steps in scenarios:
        rng = np.random.default_rng(seed)
        stream = make(wcube, rng, n_requests, steps)
        # verify applies to BOTH runs so the ratio stays a planning
        # comparison, not a verification-overhead artifact
        cold_wall, _ = _run_stream(wcube.cube, stream, delta=False,
                                   verify=verify)
        warm_wall, stats = _run_stream(wcube.cube, stream, delta=True,
                                       verify=verify)
        rows.append({
            "scenario": name,
            "requests": n_requests,
            "drift_steps": steps,
            "delta_hits": stats.delta_hits,
            "delta_hit_rate": (stats.delta_hits / stats.misses
                               if stats.misses else 0.0),
            "cold_plan_ms": cold_wall / n_requests * 1e3,
            "warm_plan_ms": warm_wall / n_requests * 1e3,
            "speedup": cold_wall / warm_wall,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="small stream for CI (100 requests)")
    ap.add_argument("--verify", action="store_true",
                    help="verify_plan every spliced plan while timing")
    ap.add_argument("--out", default="BENCH_delta.json")
    args = ap.parse_args()

    n = 100 if args.fast else 400
    rows = bench(n_requests=n, verify=args.verify)
    Path(args.out).write_text(
        json.dumps({"bench": "delta", "rows": rows}, indent=2) + "\n")

    print("scenario,requests,delta_hits,delta_hit_rate,"
          "cold_plan_ms,warm_plan_ms,speedup")
    for r in rows:
        print(f"{r['scenario']},{r['requests']},{r['delta_hits']},"
              f"{r['delta_hit_rate']:.2f},{r['cold_plan_ms']:.2f},"
              f"{r['warm_plan_ms']:.2f},{r['speedup']:.1f}")
    worst = min(r["speedup"] for r in rows)
    print(f"# worst-case warm-drift speedup {worst:.1f}x "
          f"({'PASS' if worst >= 5 else 'FAIL'}: target >= 5x)")


if __name__ == "__main__":
    main()
