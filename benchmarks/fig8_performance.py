"""Paper Fig. 8 reproduction: slicing-time scaling.

8a/8b — slicing vs total algorithm time, by #extracted points, for
request dims 2–5 (paper: ~linear in points, ~independent of dim).
8c — union-of-subshapes vs single shape (paper: unions cost more).
8d — box vs disk vs polygon primitives.

All timings on the host CPU like the paper's M1 measurements; the
quantity of interest is the *scaling*, not absolute walltime.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Box, Disk, OrderedAxis, Polygon, Request, Slicer,
                        TensorDatacube, Union)


def _cube(ndim: int, size: int = 64) -> TensorDatacube:
    axes = [OrderedAxis(f"ax{i}", np.arange(float(size)))
            for i in range(ndim)]
    return TensorDatacube(axes)


def _run(cube, request, repeats: int = 3):
    best = None
    for _ in range(repeats):
        plan, stats = Slicer(cube).extract_plan(request)
        rec = (plan.n_points, stats.slicing_time_s, stats.total_time_s,
               stats.n_slices)
        best = rec if best is None or rec[1] < best[1] else best
    return best


def fig8a_b() -> list[dict]:
    """Slicing + total time vs #points for dims 2..5."""
    rows = []
    for ndim in (2, 3, 4, 5):
        cube = _cube(ndim)
        for width in (2, 4, 8, 16, 24):
            if width ** ndim > 2_000_000:
                continue
            names = tuple(f"ax{i}" for i in range(ndim))
            req = Request([Box(names, [0.0] * ndim,
                               [float(width - 1)] * ndim)])
            n, ts, tt, ns = _run(cube, req)
            rows.append(dict(fig="8ab", ndim=ndim, n_points=n,
                             slicing_s=ts, total_s=tt, n_slices=ns))
    return rows


def fig8c() -> list[dict]:
    """Union of k sub-boxes tiling [0,48)² vs the single box."""
    cube = _cube(2)
    rows = []
    for k in (1, 2, 4, 8):
        w = 48 // k
        shapes = [Box(("ax0", "ax1"), [i * w, 0.0],
                      [(i + 1) * w - 1e-9, 47.0]) for i in range(k)]
        req = Request([Union(shapes)]) if k > 1 else Request(shapes)
        n, ts, tt, ns = _run(cube, req)
        rows.append(dict(fig="8c", n_subshapes=k, n_points=n,
                         slicing_s=ts, total_s=tt, n_slices=ns))
    return rows


def fig8d() -> list[dict]:
    """Box vs disk vs polygon(square) at matched extents."""
    cube = _cube(2)
    rows = []
    for r in (4, 8, 16, 24):
        shapes = {
            "box": Box(("ax0", "ax1"), [32.0 - r, 32.0 - r],
                       [32.0 + r, 32.0 + r]),
            "disk": Disk(("ax0", "ax1"), (32.0, 32.0), float(r),
                         segments=32),
            "polygon": Polygon(("ax0", "ax1"), np.array(
                [[32.0 - r, 32.0 - r], [32.0 + r, 32.0 - r],
                 [32.0 + r, 32.0 + r], [32.0 - r, 32.0 + r]])),
        }
        for name, shape in shapes.items():
            n, ts, tt, ns = _run(cube, Request([shape]))
            rows.append(dict(fig="8d", shape=name, radius=r, n_points=n,
                             slicing_s=ts, total_s=tt, n_slices=ns))
    return rows


def linearity_check(rows: list[dict]) -> dict:
    """Paper claim: slicing time ~linear in points, ~dim-independent."""
    import numpy as np

    by_dim = {}
    for r in rows:
        if r["fig"] == "8ab" and r["n_points"] > 8:
            by_dim.setdefault(r["ndim"], []).append(
                (r["n_points"], r["slicing_s"]))
    slopes = {}
    for d, pts in by_dim.items():
        pts = np.asarray(sorted(pts))
        if len(pts) >= 2:
            slopes[d] = float(np.polyfit(pts[:, 0], pts[:, 1], 1)[0])
    return {"us_per_point_by_dim": {d: s * 1e6
                                    for d, s in slopes.items()}}
