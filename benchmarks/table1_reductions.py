"""Paper Table 1 reproduction: data-reduction factors + timings.

The meteorology rows run on the real O1280 octahedral geometry
(6 599 680 points/field × float64 = the paper's "50.4 MB"); the MRI row
on a 512³ float64 volume ("1 GB").  Byte counts are computed from
extraction *plans* (no payload materialisation — the cube is petabyte-
class by construction).

Columns mirror the paper: traditional bytes, bbox bytes, polytope
bytes, reduction factors, slicing + total times.
"""

from __future__ import annotations

import numpy as np

from repro.core import (BoundingBoxExtractor, Box, Disk, OrderedAxis,
                        Path, PolytopeExtractor, Request, Select, Span,
                        TensorDatacube, TraditionalExtractor)
from repro.dataplane.weather import COUNTRIES, WeatherCube


def _row(name, cube, request, field_axes=("lat", "lon")):
    pe = PolytopeExtractor(getattr(cube, "cube", cube))
    bb = BoundingBoxExtractor(pe.datacube)
    tr = TraditionalExtractor(pe.datacube, field_axes=field_axes)
    plan, stats = pe.plan(request)
    box_plan = bb.plan(request)
    trad = tr.nbytes(request)
    return dict(
        example=name,
        traditional_bytes=int(trad),
        bbox_bytes=int(box_plan.nbytes),
        polytope_bytes=int(plan.nbytes),
        n_points=plan.n_points,
        reduction_vs_traditional=(trad / max(plan.nbytes, 1)),
        reduction_vs_bbox=(box_plan.nbytes / max(plan.nbytes, 1)),
        slicing_s=stats.slicing_time_s,
        total_s=stats.total_time_s,
    )


def meteorology_rows(n: int = 1280) -> list[dict]:
    rows = []

    # rows 1-3: orthogonal requests (polytope == bbox, paper rows 1-3)
    wc1 = WeatherCube(n=n, n_times=1, n_levels=1)
    g = COUNTRIES["germany"]
    rows.append(_row(
        "box_around_germany", wc1,
        Request([Select("time", [0.0]), Select("level", [0.0]),
                 Box(("lat", "lon"), g.min(0), g.max(0))])))

    wc2 = WeatherCube(n=n, n_times=112, n_levels=1)   # 14 d @ 3-hourly
    rows.append(_row(
        "timeseries_london_14d", wc2,
        wc2.timeseries_request(51.5, -0.1 % 360, 0.0,
                               111 * 3600.0)))

    wc3 = WeatherCube(n=n, n_times=1, n_levels=20)
    rows.append(_row("vertical_profile_rome_20l", wc3,
                     wc3.profile_request(41.9, 12.5)))

    # rows 4-7: non-orthogonal shapes
    rows.append(_row("country_shape_france", wc1,
                     wc1.country_request("france")))
    rows.append(_row("country_shape_norway", wc1,
                     wc1.country_request("norway")))

    wc4 = WeatherCube(n=n, n_times=9, n_levels=17)
    wps = np.stack([
        np.linspace(0, 8 * 3600.0, 10),
        np.concatenate([np.linspace(2, 16, 5),
                        np.linspace(16, 2, 5)]),
        np.linspace(48.85, 40.7, 10),
        np.linspace(2.35, -74.0, 10) % 360,
    ], axis=1)
    # unwrap lon monotonically for the sweep (Paris 2.35° → NY 286°)
    wps[:, 3] = np.where(wps[:, 3] > 180, wps[:, 3] - 360, wps[:, 3])
    rows.append(_row(
        "flight_path_paris_ny", wc4,
        Request([Path(("time", "level", "lat", "lon"),
                      Box(("level", "lat", "lon"),
                          [-0.5, -0.35, -0.35], [0.5, 0.35, 0.35]),
                      wps)])))
    return rows


def mri_row(size: int = 512) -> dict:
    """Blood-vessel sweep through a 512³ float64 MRI volume."""
    axes = [OrderedAxis(nm, np.arange(float(size)))
            for nm in ("z", "y", "x")]
    cube = TensorDatacube(axes, dtype=np.float64)
    t = np.linspace(0, 1, 24)
    centerline = np.stack([
        40 + t * 430,
        256 + 90 * np.sin(3.0 * t * np.pi),
        256 + 70 * np.cos(2.0 * t * np.pi),
    ], axis=1)
    vessel = Request([Path(("z", "y", "x"),
                           Disk(("y", "x"), (0.0, 0.0), 1.6,
                                segments=12),
                           centerline)])

    return _row("mri_blood_vessel", cube, vessel, field_axes=("y", "x"))


def table1(n: int = 1280, mri_size: int = 512) -> list[dict]:
    return meteorology_rows(n) + [mri_row(mri_size)]
