"""Paper Table 1 reproduction: data-reduction factors + timings.

The meteorology rows run on the real O1280 octahedral geometry
(6 599 680 points/field × float64 = the paper's "50.4 MB"); the MRI row
on a 512³ float64 volume ("1 GB").  Byte counts are computed from
extraction *plans* (no payload materialisation — the cube is petabyte-
class by construction).

Columns mirror the paper: traditional bytes, bbox bytes, polytope
bytes, reduction factors, slicing + total times.

Run as a script to emit ``BENCH_extraction.json`` (reduction factor,
plan time, bytes moved per scenario — including the irregular
transformed-cube scenarios) so the perf trajectory is tracked
PR-over-PR:

  PYTHONPATH=src python benchmarks/table1_reductions.py [--full]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import (BoundingBoxExtractor, Box, Disk, OrderedAxis,
                        Path, PolytopeExtractor, Request, Select, Span,
                        TensorDatacube, TraditionalExtractor)
from repro.dataplane.weather import (COUNTRIES, IrregularWeatherCube,
                                     WeatherCube)


def _row(name, cube, request, field_axes=("lat", "lon")):
    pe = PolytopeExtractor(getattr(cube, "cube", cube))
    bb = BoundingBoxExtractor(pe.datacube)
    tr = TraditionalExtractor(pe.datacube, field_axes=field_axes)
    plan, stats = pe.plan(request)
    box_plan = bb.plan(request)
    trad = tr.nbytes(request)
    return dict(
        example=name,
        traditional_bytes=int(trad),
        bbox_bytes=int(box_plan.nbytes),
        polytope_bytes=int(plan.nbytes),
        n_points=plan.n_points,
        reduction_vs_traditional=(trad / max(plan.nbytes, 1)),
        reduction_vs_bbox=(box_plan.nbytes / max(plan.nbytes, 1)),
        slicing_s=stats.slicing_time_s,
        total_s=stats.total_time_s,
    )


def meteorology_rows(n: int = 1280) -> list[dict]:
    rows = []

    # rows 1-3: orthogonal requests (polytope == bbox, paper rows 1-3)
    wc1 = WeatherCube(n=n, n_times=1, n_levels=1)
    g = COUNTRIES["germany"]
    rows.append(_row(
        "box_around_germany", wc1,
        Request([Select("time", [0.0]), Select("level", [0.0]),
                 Box(("lat", "lon"), g.min(0), g.max(0))])))

    wc2 = WeatherCube(n=n, n_times=112, n_levels=1)   # 14 d @ 3-hourly
    rows.append(_row(
        "timeseries_london_14d", wc2,
        wc2.timeseries_request(51.5, -0.1 % 360, 0.0,
                               111 * 3600.0)))

    wc3 = WeatherCube(n=n, n_times=1, n_levels=20)
    rows.append(_row("vertical_profile_rome_20l", wc3,
                     wc3.profile_request(41.9, 12.5)))

    # rows 4-7: non-orthogonal shapes
    rows.append(_row("country_shape_france", wc1,
                     wc1.country_request("france")))
    rows.append(_row("country_shape_norway", wc1,
                     wc1.country_request("norway")))

    wc4 = WeatherCube(n=n, n_times=9, n_levels=17)
    wps = np.stack([
        np.linspace(0, 8 * 3600.0, 10),
        np.concatenate([np.linspace(2, 16, 5),
                        np.linspace(16, 2, 5)]),
        np.linspace(48.85, 40.7, 10),
        np.linspace(2.35, -74.0, 10) % 360,
    ], axis=1)
    # unwrap lon monotonically for the sweep (Paris 2.35° → NY 286°)
    wps[:, 3] = np.where(wps[:, 3] > 180, wps[:, 3] - 360, wps[:, 3])
    rows.append(_row(
        "flight_path_paris_ny", wc4,
        Request([Path(("time", "level", "lat", "lon"),
                      Box(("level", "lat", "lon"),
                          [-0.5, -0.35, -0.35], [0.5, 0.35, 0.35]),
                      wps)])))
    return rows


def mri_row(size: int = 512) -> dict:
    """Blood-vessel sweep through a 512³ float64 MRI volume."""
    axes = [OrderedAxis(nm, np.arange(float(size)))
            for nm in ("z", "y", "x")]
    cube = TensorDatacube(axes, dtype=np.float64)
    t = np.linspace(0, 1, 24)
    centerline = np.stack([
        40 + t * 430,
        256 + 90 * np.sin(3.0 * t * np.pi),
        256 + 70 * np.cos(2.0 * t * np.pi),
    ], axis=1)
    vessel = Request([Path(("z", "y", "x"),
                           Disk(("y", "x"), (0.0, 0.0), 1.6,
                                segments=12),
                           centerline)])

    return _row("mri_blood_vessel", cube, vessel, field_axes=("y", "x"))


def irregular_rows(n_lat: int = 320, n_lon: int = 640) -> list[dict]:
    """Irregular transformed-cube scenarios (DESIGN.md §2.5): merged
    datetime, mapped Gaussian latitudes, cyclic longitude with a
    cross-seam country crop — the planner stays exact while the index
    space stops being a regular lattice."""
    iwc = IrregularWeatherCube(n_dates=2, times_per_day=4, n_levels=3,
                               n_lat=n_lat, n_lon=n_lon)
    return [
        _row("irregular_uk_cross_seam", iwc, iwc.country_request("uk")),
        _row("irregular_seam_box", iwc,
             iwc.seam_box_request(35.0, 62.0, -25.0, 25.0)),
        _row("irregular_ts_across_midnight", iwc,
             iwc.timeseries_request(51.5, 0.0, 43200.0,
                                    86400.0 + 43200.0)),
    ]


def table1(n: int = 1280, mri_size: int = 512) -> list[dict]:
    return meteorology_rows(n) + [mri_row(mri_size)]


def write_bench(rows: list[dict],
                out_path: str = "BENCH_extraction.json") -> None:
    """Persist the extraction trajectory: reduction factor, plan time,
    bytes moved per scenario."""
    payload = {
        "bench": "extraction",
        "rows": [dict(example=r["example"],
                      polytope_bytes=r["polytope_bytes"],
                      bbox_bytes=r["bbox_bytes"],
                      traditional_bytes=r["traditional_bytes"],
                      n_points=r["n_points"],
                      reduction_vs_traditional=r["reduction_vs_traditional"],
                      reduction_vs_bbox=r["reduction_vs_bbox"],
                      plan_time_s=r["total_s"]) for r in rows],
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale O1280 / 512³ cubes")
    ap.add_argument("--out", default="BENCH_extraction.json")
    args = ap.parse_args()
    n = 1280 if args.full else 128
    rows = table1(n=n, mri_size=512 if args.full else 128)
    rows += irregular_rows(*((640, 1280) if args.full else (320, 640)))
    for r in rows:
        print(f"{r['example']}: {r['polytope_bytes']:,} B, "
              f"reduction {r['reduction_vs_traditional']:,.0f}× vs "
              f"traditional, {r['reduction_vs_bbox']:.2f}× vs bbox, "
              f"plan {r['total_s'] * 1e3:.1f} ms")
    write_bench(rows, args.out)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
