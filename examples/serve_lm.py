"""Serving example: continuous batching over a paged KV cache whose
page reads are Polytope extraction plans.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.models.transformer import TransformerConfig, init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main() -> None:
    cfg = TransformerConfig(
        name="serve-demo", vocab=512, d_model=128, n_layers=4,
        n_heads=8, n_kv_heads=4, d_head=16, d_ff=512, q_chunk=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, EngineConfig(
        max_batch=4, max_seq=128, page_size=16, n_pages=128))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(10):
        engine.submit(Request(
            prompt=rng.integers(0, cfg.vocab,
                                int(rng.integers(8, 48))).astype(np.int32),
            max_new_tokens=12))
    done = engine.run()
    dt = time.time() - t0

    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {n_tok} new tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s, CPU)")
    print(f"page-pool utilization after drain: "
          f"{engine.pager.utilization:.0%} (all pages reclaimed)")
    r = done[0]
    print(f"sample: prompt[:8]={r.prompt[:8].tolist()} "
          f"→ out={r.out_tokens}")


if __name__ == "__main__":
    main()
