"""RecSys example: DLRM CTR training where every embedding lookup is a
Polytope categorical-axis extraction (EmbeddingBag = plan + exact-byte
gather + segment-sum), with checkpoint/restart fault tolerance.

  PYTHONPATH=src python examples/train_recsys.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dataplane.recsys import ClickStream
from repro.models.recsys import DLRMConfig, dlrm_init, dlrm_loss
from repro.train.fault import FaultConfig, Supervisor
from repro.train.optimizer import OptimizerConfig
from repro.train.train_state import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm")
    args = ap.parse_args()

    cfg = DLRMConfig(rows=50_000, embed_dim=16, n_sparse=8,
                     bot_mlp=(64, 32, 16), top_mlp=(64, 32, 1))
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(kind="adamw", lr=1e-3, warmup_steps=20,
                           total_steps=args.steps)
    state = init_train_state(params, ocfg)
    step = jax.jit(make_train_step(
        lambda p, b: (dlrm_loss(p, cfg, b), {}), ocfg))

    cs = ClickStream(n_sparse=cfg.n_sparse, rows=cfg.rows)

    def data_fn(s):
        b = cs.batch(s, args.batch)
        return {k: jnp.asarray(v) for k, v in b.items()}

    t0 = time.time()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 25 == 0:
            print(f"step {s:4d}  bce {losses[-1]:.4f}")

    sup = Supervisor(FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
                     step, data_fn)
    sup.run(state, args.steps, on_metrics=on_metrics)
    print(f"\nBCE {np.mean(losses[:10]):.4f} → "
          f"{np.mean(losses[-10:]):.4f} over {args.steps} steps "
          f"({time.time() - t0:.1f}s); AUC-proxy improving ⇢ the hidden "
          f"CTR model is being learned through extracted embeddings")


if __name__ == "__main__":
    main()
