"""End-to-end driver: train a ~100M-parameter LM for a few hundred
steps, with Polytope-planned token batches, checkpointing and a
simulated preemption + restart (deliverable b).

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dataplane.tokens import TokenCube
from repro.models.transformer import (TransformerConfig, init_params,
                                      loss_fn)
from repro.train.fault import FaultConfig, Supervisor
from repro.train.optimizer import OptimizerConfig
from repro.train.train_state import init_train_state, make_train_step


def lm_100m() -> TransformerConfig:
    # ~100M params: 12 layers × d512 × ff2048, 32k vocab
    return TransformerConfig(
        name="lm-100m", vocab=32_768, d_model=512, n_layers=12,
        n_heads=8, n_kv_heads=4, d_head=64, d_ff=2048, q_chunk=None)


def lm_small() -> TransformerConfig:
    # CPU-budget variant for CI / laptops (same code path)
    return TransformerConfig(
        name="lm-small", vocab=4096, d_model=128, n_layers=4,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=512, q_chunk=None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--preempt-at", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--preset", choices=["100m", "small"],
                    default="100m")
    args = ap.parse_args()

    cfg = lm_100m() if args.preset == "100m" else lm_small()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params")

    ocfg = OptimizerConfig(kind="adamw", lr=3e-4, warmup_steps=50,
                           total_steps=args.steps)
    state = init_train_state(params, ocfg)
    step = jax.jit(make_train_step(
        lambda p, b: loss_fn(p, cfg, b["tokens"], b["labels"]), ocfg))

    tc = TokenCube(vocab=cfg.vocab, n_docs=64, doc_len=1024)

    def data_fn(s):
        b = tc.batch(s, args.batch, args.seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    crashed = {"done": False}

    def injector(s):
        if s == args.preempt_at and not crashed["done"]:
            crashed["done"] = True
            print(f"!! simulated preemption at step {s}")
            raise RuntimeError("simulated preemption")

    t0 = time.time()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 20 == 0:
            tok_s = args.batch * args.seq * (s + 1) / (time.time() - t0)
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  {tok_s:,.0f} tok/s")

    sup = Supervisor(FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
                     step, data_fn, fault_injector=injector)
    sup.run(state, args.steps, on_metrics=on_metrics)
    print(f"\nfinal loss {np.mean(losses[-10:]):.4f} "
          f"(start {np.mean(losses[:10]):.4f}); "
          f"{args.steps} steps in {time.time() - t0:.1f}s; "
          f"restarts: {sup.restarts}")


if __name__ == "__main__":
    main()
