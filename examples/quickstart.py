"""Quickstart: the Polytope algorithm in five minutes.

Builds the paper's datacube (an octahedral weather grid), extracts a
country polygon, a time-series, and a flight path, and prints the
byte-reduction table vs the bounding-box / whole-field baselines —
a miniature of the paper's Table 1.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (BoundingBoxExtractor, PolytopeExtractor,
                        TraditionalExtractor)
from repro.dataplane.weather import WeatherCube, paris_newyork_path


def main() -> None:
    # O128 grid: 66 560 points/field (the paper uses O1280 = 6.6M;
    # same geometry, friendlier for a quickstart)
    wc = WeatherCube(n=128, n_times=8, n_levels=10)
    data = wc.field_data(seed=0)
    pe = PolytopeExtractor(wc.cube)
    bb = BoundingBoxExtractor(wc.cube)
    tr = TraditionalExtractor(wc.cube)

    requests = {
        "country: France": wc.country_request("france"),
        "country: Norway": wc.country_request("norway"),
        "timeseries London 8 steps": wc.timeseries_request(
            51.5, 0.0, 0.0, 7 * 3600.0),
        "flight path Paris→NY": wc.flight_path_request(
            paris_newyork_path(wc), width=1.5),
    }

    print(f"{'request':<28}{'polytope':>10}{'bbox':>12}"
          f"{'whole-field':>14}{'vs bbox':>9}{'vs trad':>10}")
    print("-" * 83)
    for name, req in requests.items():
        res = pe.extract(req, data)
        box = bb.plan(req)
        trad = tr.nbytes(req)
        red_b = box.nbytes / max(res.plan.nbytes, 1)
        red_t = trad / max(res.plan.nbytes, 1)
        print(f"{name:<28}{res.plan.nbytes:>9,}B{box.nbytes:>11,}B"
              f"{trad:>13,}B{red_b:>8.1f}x{red_t:>9,.0f}x")

    res = pe.extract(requests["country: France"], data)
    print(f"\nFrance: {res.plan.n_points} points in "
          f"{res.plan.n_runs} contiguous runs; mean temp "
          f"{float(np.mean(res.values)):.2f} "
          f"(slicing {res.stats.slicing_time_s * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
