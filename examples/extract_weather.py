"""Domain-interface tour (paper §4): every Table-1 request type against
a synthetic weather cube, printing the index-tree → plan → gather flow.

  PYTHONPATH=src python examples/extract_weather.py
"""

import numpy as np

from repro.core import PolytopeExtractor, Slicer
from repro.dataplane.weather import (COUNTRIES, WeatherCube,
                                     paris_newyork_path)


def main() -> None:
    wc = WeatherCube(n=96, n_times=8, n_levels=10)
    data = wc.field_data(seed=7)
    pe = PolytopeExtractor(wc.cube)
    print(f"cube: {wc.cube.n_elements:,} elements "
          f"({wc.cube.nbytes / 2**20:.0f} MiB), octahedral O{wc.n}, "
          f"{wc.n_times} times × {wc.n_levels} levels\n")

    demos = {
        "Italy, t=2, level=0": wc.country_request("italy",
                                                  time=2 * 3600.0),
        "London time-series (all 8 steps)": wc.timeseries_request(
            51.5, 0.0, 0.0, 7 * 3600.0),
        "Rome vertical profile (10 levels)": wc.profile_request(
            41.9, 12.5),
        "Paris→NY flight tube": wc.flight_path_request(
            paris_newyork_path(wc), width=2.0),
    }

    for name, req in demos.items():
        root, stats = Slicer(wc.cube).build_index_tree(req)
        res = pe.extract(req, data)
        plan = res.plan
        print(f"{name}")
        print(f"  index tree: depth {root.depth()}, "
              f"{plan.n_points} leaf points, "
              f"{stats.n_slices} slices "
              f"{dict(sorted(stats.n_slices_by_dim.items()))}")
        print(f"  plan: {plan.nbytes:,} B in {plan.n_runs} contiguous "
              f"runs (largest {int(plan.run_lengths.max()) if plan.n_runs else 0} elems)")
        print(f"  values: mean {float(np.mean(res.values)):.2f}, "
              f"extracted in {stats.total_time_s * 1e3:.1f} ms\n")


if __name__ == "__main__":
    main()
