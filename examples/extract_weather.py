"""Domain-interface tour (paper §4): every Table-1 request type against
a synthetic weather cube, printing the index-tree → plan → gather flow —
plus the irregular-datacube scenario (DESIGN.md §2.5): merged date/time,
mapped Gaussian latitudes, and a cross-seam UK crop on a cyclic
longitude, served through the plan cache with a seam-shifted cache hit.
Emits ``BENCH_extraction.json`` with the irregular scenario's reduction
factor, plan time, and bytes moved.

  PYTHONPATH=src python examples/extract_weather.py
"""

import json

import numpy as np

from repro.core import (BoundingBoxExtractor, Box, PolytopeExtractor,
                        Request, Select, Slicer, TraditionalExtractor)
from repro.dataplane.weather import (COUNTRIES, IrregularWeatherCube,
                                     WeatherCube, paris_newyork_path)
from repro.serve.extraction import ExtractionService


def irregular_scenarios(iwc: IrregularWeatherCube) -> dict:
    return {
        "uk_cross_seam_crop": iwc.country_request("uk"),
        "seam_box_-20_20": iwc.seam_box_request(40.0, 60.0, -20.0, 20.0),
        "timeseries_across_midnight": iwc.timeseries_request(
            51.5, 0.0, 43200.0, 86400.0 + 43200.0),
    }


def run_irregular(out_path: str = "BENCH_extraction.json") -> None:
    print("— irregular datacube (merged datetime · mapped Gaussian lat · "
          "cyclic lon) —")
    iwc = IrregularWeatherCube(n_lat=160, n_lon=320)
    data = iwc.field_data(seed=3)
    svc = ExtractionService(iwc.cube)
    bb = BoundingBoxExtractor(iwc.cube)
    tr = TraditionalExtractor(iwc.cube, field_axes=("lat", "lon"))
    print(f"cube: {iwc.cube.n_elements:,} elements, logical axes "
          f"{iwc.cube.axis_names}, periods {iwc.cube.axis_periods()}\n")

    rows = []
    for name, req in irregular_scenarios(iwc).items():
        res = svc.extract(req, data)
        plan, stats = res.plan, res.stats
        trad = tr.nbytes(req)
        box = bb.plan(req).nbytes
        rows.append(dict(
            example=name,
            polytope_bytes=int(plan.nbytes),
            bbox_bytes=int(box),
            traditional_bytes=int(trad),
            n_points=plan.n_points,
            n_runs=plan.n_runs,
            reduction_vs_traditional=trad / max(plan.nbytes, 1),
            reduction_vs_bbox=box / max(plan.nbytes, 1),
            plan_time_s=stats.total_time_s if stats else 0.0,
        ))
        print(f"{name}: {plan.n_points} points, {plan.nbytes:,} B in "
              f"{plan.n_runs} runs, reduction {trad / max(plan.nbytes, 1):,.0f}× "
              f"vs whole-field, values mean {float(np.mean(res.values)):.2f}")

    # Seam-shifted re-request: same geometry expressed +360° away must
    # hit the plan cache (canonicalization modulo the period).
    shifted = Request([Select("datetime", [0.0]), Select("level", [0.0]),
                       Box(("lat", "lon"), [40.0, 340.0], [60.0, 380.0])])
    base = iwc.seam_box_request(40.0, 60.0, -20.0, 20.0)
    svc.extract(base)
    hit = svc.extract(shifted)
    print(f"seam-shifted box (+360°) served from cache: {hit.cached}\n")

    payload = {"bench": "extraction", "rows": rows,
               "seam_shift_cache_hit": bool(hit.cached)}
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {out_path}")


def main() -> None:
    wc = WeatherCube(n=96, n_times=8, n_levels=10)
    data = wc.field_data(seed=7)
    pe = PolytopeExtractor(wc.cube)
    print(f"cube: {wc.cube.n_elements:,} elements "
          f"({wc.cube.nbytes / 2**20:.0f} MiB), octahedral O{wc.n}, "
          f"{wc.n_times} times × {wc.n_levels} levels\n")

    demos = {
        "Italy, t=2, level=0": wc.country_request("italy",
                                                  time=2 * 3600.0),
        "London time-series (all 8 steps)": wc.timeseries_request(
            51.5, 0.0, 0.0, 7 * 3600.0),
        "Rome vertical profile (10 levels)": wc.profile_request(
            41.9, 12.5),
        "Paris→NY flight tube": wc.flight_path_request(
            paris_newyork_path(wc), width=2.0),
    }

    for name, req in demos.items():
        root, stats = Slicer(wc.cube).build_index_tree(req)
        res = pe.extract(req, data)
        plan = res.plan
        print(f"{name}")
        print(f"  index tree: depth {root.depth()}, "
              f"{plan.n_points} leaf points, "
              f"{stats.n_slices} slices "
              f"{dict(sorted(stats.n_slices_by_dim.items()))}")
        print(f"  plan: {plan.nbytes:,} B in {plan.n_runs} contiguous "
              f"runs (largest {int(plan.run_lengths.max()) if plan.n_runs else 0} elems)")
        print(f"  values: mean {float(np.mean(res.values)):.2f}, "
              f"extracted in {stats.total_time_s * 1e3:.1f} ms\n")

    run_irregular()


if __name__ == "__main__":
    main()
